//! The real Executor: runs a Saturn execution plan against actual AOT
//! executables through the PJRT runtime.
//!
//! Architecture (the role Ray plays in the paper, adapted to one machine;
//! no async runtime is vendored offline, so the event loop is built on
//! std threads + channels):
//!
//! - a **compute thread** owns the [`crate::runtime::Runtime`] (PJRT
//!   handles are not `Sync`) and serves train-step requests over a
//!   channel — plain `Vec<f32>`/`Vec<i32>` payloads cross the channel,
//!   literals are built thread-locally;
//! - **device slots** emulate the cluster's GPUs: a task's gang must
//!   acquire all its slots simultaneously before any step runs, and holds
//!   them to completion — the Executor "taints" slots to the plan exactly
//!   like Saturn taints Ray-owned GPUs;
//! - **training jobs** are worker threads stepping their model through the
//!   compute handle, logging the loss curve.
//!
//! Throughput note: this is a CPU testbed — multi-GPU *speedups* are the
//! simulator's job; the executor proves the full stack composes (plan →
//! gang placement → real SGD steps → real loss curves).

use crate::runtime::{literal_f32, literal_i32, Runtime};
use crate::sched::Schedule;
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};

/// A request served by the compute thread.
enum ComputeMsg {
    /// Initialize parameters: artifact's `init` entry point.
    Init {
        artifact: String,
        seed: i32,
        reply: mpsc::Sender<Result<Vec<f32>>>,
    },
    /// One SGD step: (params, tokens, targets, lr) → (params', loss).
    Step {
        artifact: String,
        params: Vec<f32>,
        tokens: Vec<i32>,
        targets: Vec<i32>,
        lr: f32,
        reply: mpsc::Sender<Result<(Vec<f32>, f32)>>,
    },
    /// Shut the thread down.
    Shutdown,
}

/// Cloneable handle to the compute thread.
#[derive(Clone)]
pub struct ComputeHandle {
    tx: mpsc::Sender<ComputeMsg>,
}

impl ComputeHandle {
    /// Spawn the compute thread over an artifacts directory.
    ///
    /// The [`Runtime`] is constructed *on* the thread (PJRT handles are
    /// `!Send`); load errors are relayed back through a startup handshake.
    pub fn spawn(artifacts_dir: impl Into<std::path::PathBuf>) -> Result<(Self, std::thread::JoinHandle<()>)> {
        let dir = artifacts_dir.into();
        let (tx, rx) = mpsc::channel::<ComputeMsg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let join = std::thread::spawn(move || {
            let mut runtime = match Runtime::load(&dir) {
                Ok(r) => {
                    let _ = ready_tx.send(Ok(()));
                    r
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            while let Ok(msg) = rx.recv() {
                match msg {
                    ComputeMsg::Shutdown => break,
                    ComputeMsg::Init { artifact, seed, reply } => {
                        let _ = reply.send(do_init(&mut runtime, &artifact, seed));
                    }
                    ComputeMsg::Step { artifact, params, tokens, targets, lr, reply } => {
                        let _ = reply.send(do_step(&mut runtime, &artifact, params, tokens, targets, lr));
                    }
                }
            }
        });
        ready_rx.recv().map_err(|_| anyhow!("compute thread died during startup"))??;
        Ok((Self { tx }, join))
    }

    /// Initialize a model's flat parameter vector.
    pub fn init(&self, artifact: &str, seed: i32) -> Result<Vec<f32>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(ComputeMsg::Init { artifact: artifact.to_string(), seed, reply })
            .map_err(|_| anyhow!("compute thread gone"))?;
        rx.recv().map_err(|_| anyhow!("compute thread dropped reply"))?
    }

    /// Run one training step.
    pub fn step(
        &self,
        artifact: &str,
        params: Vec<f32>,
        tokens: Vec<i32>,
        targets: Vec<i32>,
        lr: f32,
    ) -> Result<(Vec<f32>, f32)> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(ComputeMsg::Step { artifact: artifact.to_string(), params, tokens, targets, lr, reply })
            .map_err(|_| anyhow!("compute thread gone"))?;
        rx.recv().map_err(|_| anyhow!("compute thread dropped reply"))?
    }

    /// Ask the compute thread to exit.
    pub fn shutdown(&self) {
        let _ = self.tx.send(ComputeMsg::Shutdown);
    }
}

fn do_init(rt: &mut Runtime, artifact: &str, seed: i32) -> Result<Vec<f32>> {
    let out = rt.execute(artifact, &[literal_i32(&[seed], &[])?])?;
    out[0].to_vec::<f32>().map_err(|e| anyhow!("init params: {e:?}"))
}

fn do_step(rt: &mut Runtime, artifact: &str, params: Vec<f32>, tokens: Vec<i32>, targets: Vec<i32>, lr: f32) -> Result<(Vec<f32>, f32)> {
    let art = rt.manifest().get(artifact).ok_or_else(|| anyhow!("unknown artifact {artifact}"))?;
    let (b, s) = (art.meta.batch, art.meta.seq);
    let p = art.meta.param_count;
    if params.len() != p || tokens.len() != b * s || targets.len() != b * s {
        return Err(anyhow!("{artifact}: bad payload sizes"));
    }
    let inputs = vec![
        literal_f32(&params, &[p])?,
        literal_i32(&tokens, &[b, s])?,
        literal_i32(&targets, &[b, s])?,
        literal_f32(&[lr], &[])?,
    ];
    let out = rt.execute(artifact, &inputs)?;
    let new_params = out[0].to_vec::<f32>().map_err(|e| anyhow!("params out: {e:?}"))?;
    let loss = out[1].to_vec::<f32>().map_err(|e| anyhow!("loss out: {e:?}"))?[0];
    Ok((new_params, loss))
}

/// Gang-acquirable device slots for one emulated node.
pub struct DeviceSlots {
    state: Mutex<Vec<bool>>,
    cv: Condvar,
}

impl DeviceSlots {
    /// A node with `n` device slots.
    pub fn new(n: usize) -> Arc<Self> {
        Arc::new(Self { state: Mutex::new(vec![true; n]), cv: Condvar::new() })
    }

    /// Acquire `n` slots simultaneously (a gang); blocks until available.
    pub fn acquire(self: &Arc<Self>, n: usize) -> Vec<usize> {
        let mut free = self.state.lock().unwrap();
        loop {
            let avail: Vec<usize> = free.iter().enumerate().filter(|(_, f)| **f).map(|(i, _)| i).collect();
            if avail.len() >= n {
                let gang: Vec<usize> = avail.into_iter().take(n).collect();
                for &g in &gang {
                    free[g] = false;
                }
                return gang;
            }
            free = self.cv.wait(free).unwrap();
        }
    }

    /// Release a gang.
    pub fn release(self: &Arc<Self>, gang: &[usize]) {
        let mut free = self.state.lock().unwrap();
        for &g in gang {
            free[g] = true;
        }
        drop(free);
        self.cv.notify_all();
    }

    /// Number of currently free slots.
    pub fn free_count(self: &Arc<Self>) -> usize {
        self.state.lock().unwrap().iter().filter(|f| **f).count()
    }
}

/// Deterministic synthetic corpus: a noisy affine token chain the tiny LM
/// can actually learn (loss drops quickly from ln(vocab)).
pub struct SyntheticCorpus {
    vocab: usize,
    state: u64,
}

impl SyntheticCorpus {
    /// New corpus stream with a seed.
    pub fn new(vocab: usize, seed: u64) -> Self {
        Self { vocab, state: seed.wrapping_mul(0x9E3779B97F4A7C15) | 1 }
    }

    fn next_u32(&mut self) -> u32 {
        // xorshift64*
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        ((x.wrapping_mul(0x2545F4914F6CDD1D)) >> 32) as u32
    }

    /// Next (tokens, targets) minibatch of shape [batch, seq].
    /// Sequence rule: x_{i+1} = (7·x_i + 3) mod vocab, with 10% uniform
    /// noise; targets are the next token.
    pub fn batch(&mut self, batch: usize, seq: usize) -> (Vec<i32>, Vec<i32>) {
        let mut tokens = Vec::with_capacity(batch * seq);
        let mut targets = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            let mut x = (self.next_u32() as usize) % self.vocab;
            for _ in 0..seq {
                tokens.push(x as i32);
                let next = if self.next_u32() % 10 == 0 {
                    (self.next_u32() as usize) % self.vocab
                } else {
                    (7 * x + 3) % self.vocab
                };
                targets.push(next as i32);
                x = next;
            }
        }
        (tokens, targets)
    }
}

/// Result of one executed training job.
#[derive(Debug, Clone)]
pub struct JobReport {
    /// Task id.
    pub task_id: usize,
    /// (step, loss) curve.
    pub losses: Vec<(usize, f32)>,
    /// Gang slots the job ran on.
    pub gang: Vec<usize>,
    /// Wall-clock seconds including gang wait.
    pub wall_secs: f64,
}

/// Binding of a scheduled task to a runnable artifact.
#[derive(Debug, Clone)]
pub struct JobSpec {
    /// Task id (matches the schedule).
    pub task_id: usize,
    /// Artifact to step.
    pub artifact: String,
    /// Steps to run.
    pub steps: usize,
    /// Learning rate (the hyper-parameter model selection varies).
    pub lr: f32,
    /// Data seed.
    pub seed: u64,
}

/// Execute a plan's tasks with gang slot semantics over one emulated node.
///
/// Tasks launch in plan start-time order; each acquires its gang, steps
/// its model to completion through the shared compute thread, logs losses,
/// and releases the gang. Mirrors the paper's Executor "tainting" GPUs to
/// the precomputed schedule.
pub fn run_plan(
    handle: &ComputeHandle,
    slots: Arc<DeviceSlots>,
    schedule: &Schedule,
    jobs: &[JobSpec],
) -> Result<Vec<JobReport>> {
    // launch in plan start order without cloning assignments (index
    // sort), looking jobs up through a first-occurrence map — the linear
    // scan this replaces rescanned `jobs` once per assignment, O(n²) at
    // plan scale (see also `Schedule::id_index` for the schedule-keyed
    // direction).
    let mut order: Vec<usize> = (0..schedule.assignments.len()).collect();
    order.sort_by(|&x, &y| {
        let (a, b) = (&schedule.assignments[x], &schedule.assignments[y]);
        a.start.total_cmp(&b.start).then(a.task_id.cmp(&b.task_id))
    });
    let mut job_by_id: HashMap<usize, &JobSpec> = HashMap::with_capacity(jobs.len());
    for j in jobs {
        job_by_id.entry(j.task_id).or_insert(j);
    }
    let mut handles = Vec::new();
    for &i in &order {
        let a = &schedule.assignments[i];
        let Some(job) = job_by_id.get(&a.task_id).map(|j| (*j).clone()) else {
            continue;
        };
        let gang_size = a.config.gpus;
        let slots = Arc::clone(&slots);
        let handle = handle.clone();
        handles.push(std::thread::spawn(move || -> Result<JobReport> {
            let t0 = std::time::Instant::now();
            let gang = slots.acquire(gang_size);
            let report = run_job(&handle, &job, gang.clone());
            slots.release(&gang);
            report.map(|mut r| {
                r.wall_secs = t0.elapsed().as_secs_f64();
                r
            })
        }));
        // brief yield so acquisition order follows plan order
        std::thread::sleep(std::time::Duration::from_millis(2));
    }
    let mut reports = Vec::new();
    for h in handles {
        reports.push(h.join().map_err(|_| anyhow!("job thread panicked"))??);
    }
    Ok(reports)
}

fn run_job(handle: &ComputeHandle, job: &JobSpec, gang: Vec<usize>) -> Result<JobReport> {
    let mut params = handle.init(&init_name(&job.artifact), job.seed as i32)?;
    let (batch, seq, vocab) =
        parse_dims(&job.artifact).ok_or_else(|| anyhow!("artifact {} lacks dims in name", job.artifact))?;
    let mut corpus = SyntheticCorpus::new(vocab, job.seed);
    let mut losses = Vec::with_capacity(job.steps);
    for step in 0..job.steps {
        let (tokens, targets) = corpus.batch(batch, seq);
        let (new_params, loss) = handle.step(&job.artifact, params, tokens, targets, job.lr)?;
        params = new_params;
        losses.push((step, loss));
    }
    Ok(JobReport { task_id: job.task_id, losses, gang, wall_secs: 0.0 })
}

/// Artifact naming convention (see aot.py): `<family>_l{L}_h{H}_v{V}_b{B}_s{S}_train`
/// with a matching `..._init`.
pub fn init_name(train_artifact: &str) -> String {
    train_artifact.replace("_train", "_init")
}

/// Parse (batch, seq, vocab) out of the artifact name.
pub fn parse_dims(name: &str) -> Option<(usize, usize, usize)> {
    let mut batch = None;
    let mut seq = None;
    let mut vocab = None;
    for part in name.split('_') {
        if let Some(v) = part.strip_prefix('b').and_then(|x| x.parse::<usize>().ok()) {
            batch = Some(v);
        } else if let Some(v) = part.strip_prefix('s').and_then(|x| x.parse::<usize>().ok()) {
            seq = Some(v);
        } else if let Some(v) = part.strip_prefix('v').and_then(|x| x.parse::<usize>().ok()) {
            vocab = Some(v);
        }
    }
    Some((batch?, seq?, vocab?))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn corpus_deterministic() {
        let mut a = SyntheticCorpus::new(64, 9);
        let mut b = SyntheticCorpus::new(64, 9);
        assert_eq!(a.batch(4, 16), b.batch(4, 16));
        let mut c = SyntheticCorpus::new(64, 10);
        assert_ne!(a.batch(4, 16), c.batch(4, 16));
    }

    #[test]
    fn corpus_tokens_in_range() {
        let mut c = SyntheticCorpus::new(100, 1);
        let (toks, tgts) = c.batch(8, 32);
        assert_eq!(toks.len(), 256);
        assert_eq!(tgts.len(), 256);
        assert!(toks.iter().chain(&tgts).all(|&t| t >= 0 && t < 100));
    }

    #[test]
    fn corpus_mostly_follows_chain() {
        let mut c = SyntheticCorpus::new(101, 2);
        let (toks, tgts) = c.batch(16, 64);
        let follow = toks
            .iter()
            .zip(&tgts)
            .filter(|(&x, &y)| (7 * x as usize + 3) % 101 == y as usize)
            .count();
        // ~90% of transitions follow the learnable rule
        assert!(follow as f64 / toks.len() as f64 > 0.8);
    }

    #[test]
    fn name_conventions() {
        assert_eq!(init_name("tiny_l2_h64_v128_b4_s16_train"), "tiny_l2_h64_v128_b4_s16_init");
        assert_eq!(parse_dims("tiny_l2_h64_v128_b4_s16_train"), Some((4, 16, 128)));
        assert_eq!(parse_dims("nope"), None);
    }

    #[test]
    fn slots_gang_semantics() {
        let slots = DeviceSlots::new(4);
        let g1 = slots.acquire(3);
        assert_eq!(g1.len(), 3);
        assert_eq!(slots.free_count(), 1);
        // a 2-gang must wait until release
        let s2 = Arc::clone(&slots);
        let waiter = std::thread::spawn(move || s2.acquire(2));
        std::thread::sleep(std::time::Duration::from_millis(30));
        assert!(!waiter.is_finished());
        slots.release(&g1);
        let g2 = waiter.join().unwrap();
        assert_eq!(g2.len(), 2);
    }

    #[test]
    fn slots_release_restores() {
        let slots = DeviceSlots::new(2);
        let g = slots.acquire(2);
        slots.release(&g);
        assert_eq!(slots.free_count(), 2);
    }

    #[test]
    fn slots_disjoint_gangs() {
        let slots = DeviceSlots::new(4);
        let a = slots.acquire(2);
        let b = slots.acquire(2);
        assert!(a.iter().all(|x| !b.contains(x)));
    }
}
