//! User-Pluggable Parallelisms (UPPs) and the Parallelism Library.
//!
//! The paper's extensibility abstraction (§3.1): a parallelism is a black
//! box with a two-function interface —
//!
//! - `search(task, gpus) -> (knobs, minibatch runtime estimate)`, null on
//!   OOM/failure;
//! - `execute(task, gpus, knobs)`, which trains to completion (here: the
//!   executor in [`crate::exec`] drives execution; a UPP contributes its
//!   timing/memory behaviour).
//!
//! The Library is a define-once, use-anywhere registry: UPPs registered
//! under a user-chosen name are reused across models, sessions, and users.
//! Saturn ships a default library of four UPPs (DDP, FSDP, GPipe
//! pipelining, spilling) backed by the calibrated cost model; users can
//! register additional parallelisms (see `tests::custom_upp_is_selectable`)
//! without touching any Saturn internals.

use crate::cluster::Node;
use crate::costmodel::{CostEstimate, CostModel, Knobs, ParallelismKind};
use crate::trainer::Task;
use std::collections::BTreeMap;
use std::sync::Arc;

/// The result of a UPP's `search`: tuned knobs plus the runtime estimate
/// the Joint Optimizer consumes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct UppPlan {
    /// Auto-tuned execution knobs.
    pub knobs: Knobs,
    /// Runtime/memory estimate at those knobs.
    pub estimate: CostEstimate,
}

/// A User-Pluggable Parallelism (paper Listing 4's `BaseParallelism`).
pub trait Upp: Send + Sync {
    /// Registry name, e.g. `"pytorch-fsdp"`.
    fn name(&self) -> &str;

    /// Which built-in kind this UPP reports as (for display/Table-4 style
    /// output). Custom UPPs may reuse the closest kind.
    fn kind(&self) -> ParallelismKind;

    /// Tune knobs and estimate the per-minibatch runtime of `task` on
    /// `gpus` GPUs of `node`. `None` signals an OOM/failed search.
    fn search(&self, task: &Task, gpus: usize, node: &Node) -> Option<UppPlan>;
}

/// Built-in UPP: wraps one [`ParallelismKind`] of the analytic cost model.
pub struct BuiltinUpp {
    kind: ParallelismKind,
    cost: Arc<CostModel>,
}

impl BuiltinUpp {
    /// Construct for a given kind over a shared cost model.
    pub fn new(kind: ParallelismKind, cost: Arc<CostModel>) -> Self {
        Self { kind, cost }
    }
}

impl Upp for BuiltinUpp {
    fn name(&self) -> &str {
        self.kind.name()
    }

    fn kind(&self) -> ParallelismKind {
        self.kind
    }

    fn search(&self, task: &Task, gpus: usize, node: &Node) -> Option<UppPlan> {
        self.cost.search(task, self.kind, gpus, node).map(|(knobs, estimate)| UppPlan { knobs, estimate })
    }
}

/// The Parallelism Library: an ordered name → UPP registry.
#[derive(Clone, Default)]
pub struct UppRegistry {
    upps: BTreeMap<String, Arc<dyn Upp>>,
}

impl std::fmt::Debug for UppRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("UppRegistry").field("names", &self.names()).finish()
    }
}

impl UppRegistry {
    /// Empty registry.
    pub fn new() -> Self {
        Self::default()
    }

    /// The default library (paper §3.1): DDP, FSDP, GPipe, spilling, all
    /// backed by `cost`.
    pub fn default_library(cost: Arc<CostModel>) -> Self {
        let mut r = Self::new();
        for kind in ParallelismKind::ALL {
            r.register(kind.name(), Arc::new(BuiltinUpp::new(kind, Arc::clone(&cost))));
        }
        r
    }

    /// Register (or replace) a UPP under `name` (paper Listing 2).
    pub fn register(&mut self, name: &str, upp: Arc<dyn Upp>) {
        self.upps.insert(name.to_string(), upp);
    }

    /// Remove a UPP; returns true if it existed.
    pub fn unregister(&mut self, name: &str) -> bool {
        self.upps.remove(name).is_some()
    }

    /// Look up by name.
    pub fn get(&self, name: &str) -> Option<&Arc<dyn Upp>> {
        self.upps.get(name)
    }

    /// Registered names, sorted (stable enumeration order for the
    /// Plan Enumerator).
    pub fn names(&self) -> Vec<String> {
        self.upps.keys().cloned().collect()
    }

    /// Iterate (name, upp) in name order.
    pub fn iter(&self) -> impl Iterator<Item = (&String, &Arc<dyn Upp>)> {
        self.upps.iter()
    }

    /// Number of registered UPPs.
    pub fn len(&self) -> usize {
        self.upps.len()
    }

    /// True if no UPPs registered.
    pub fn is_empty(&self) -> bool {
        self.upps.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelDesc;
    use crate::trainer::{HParams, Optimizer};

    fn registry() -> UppRegistry {
        UppRegistry::default_library(Arc::new(CostModel::default()))
    }

    fn task() -> Task {
        Task::new(0, ModelDesc::gpt2_1_5b(), HParams::new(16, 1e-5, 10, Optimizer::Adam), 19_200)
    }

    #[test]
    fn default_library_has_four_upps() {
        let r = registry();
        assert_eq!(r.len(), 4);
        assert_eq!(r.names(), vec!["gpipe", "pytorch-ddp", "pytorch-fsdp", "spilling"]);
    }

    #[test]
    fn builtin_search_matches_cost_model() {
        let cost = Arc::new(CostModel::default());
        let r = UppRegistry::default_library(Arc::clone(&cost));
        let node = Node::a100(0, 8);
        let t = task();
        let via_upp = r.get("pytorch-fsdp").unwrap().search(&t, 4, &node).unwrap();
        let (knobs, est) = cost.search(&t, ParallelismKind::Fsdp, 4, &node).unwrap();
        assert_eq!(via_upp.knobs, knobs);
        assert_eq!(via_upp.estimate, est);
    }

    #[test]
    fn search_null_on_oom() {
        let r = registry();
        let node = Node::a100(0, 8);
        let t = Task::new(0, ModelDesc::gpt_j_6b(), HParams::new(16, 1e-5, 10, Optimizer::Adam), 19_200);
        assert!(r.get("pytorch-ddp").unwrap().search(&t, 8, &node).is_none());
    }

    /// A user-defined parallelism: a "megatron-like" hybrid that is 20%
    /// faster than FSDP whenever FSDP is feasible. Registering it requires
    /// no changes to Saturn — the extensibility desideratum.
    struct MegatronLike {
        cost: Arc<CostModel>,
    }

    impl Upp for MegatronLike {
        fn name(&self) -> &str {
            "megatron-hybrid"
        }
        fn kind(&self) -> ParallelismKind {
            ParallelismKind::Fsdp
        }
        fn search(&self, task: &Task, gpus: usize, node: &Node) -> Option<UppPlan> {
            let (knobs, mut est) = self.cost.search(task, ParallelismKind::Fsdp, gpus, node)?;
            est.minibatch_secs *= 0.8;
            Some(UppPlan { knobs, estimate: est })
        }
    }

    #[test]
    fn custom_upp_is_selectable() {
        let cost = Arc::new(CostModel::default());
        let mut r = UppRegistry::default_library(Arc::clone(&cost));
        r.register("megatron-hybrid", Arc::new(MegatronLike { cost: Arc::clone(&cost) }));
        assert_eq!(r.len(), 5);
        let node = Node::a100(0, 8);
        let t = task();
        let custom = r.get("megatron-hybrid").unwrap().search(&t, 8, &node).unwrap();
        let fsdp = r.get("pytorch-fsdp").unwrap().search(&t, 8, &node).unwrap();
        assert!(custom.estimate.minibatch_secs < fsdp.estimate.minibatch_secs);
    }

    #[test]
    fn unregister_removes() {
        let mut r = registry();
        assert!(r.unregister("gpipe"));
        assert!(!r.unregister("gpipe"));
        assert_eq!(r.len(), 3);
        assert!(r.get("gpipe").is_none());
    }
}
