//! Experiment / system configuration.
//!
//! A real deployment drives Saturn through config files rather than code:
//! this module defines the JSON-serializable experiment spec consumed by
//! the `saturn` CLI (`saturn run --config exp.json`) and helpers to parse
//! compact cluster specs like `"8"`, `"4x8"`, or `"2,2,4,8"`.

use crate::cluster::Cluster;
use crate::sim::{IntrospectCfg, SimConfig};
use crate::util::json::Json;

/// Which workload family to build.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkloadKind {
    /// Paper TXT: GPT-2 + GPT-J grid (12 tasks).
    Txt,
    /// Paper IMG: ViT-G + ResNet grid (12 tasks).
    Img,
}

/// Which planner to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    /// Saturn's joint optimizer.
    Saturn,
    /// Current-practice baseline (full node, human-fixed FSDP).
    CurrentPractice,
    /// Max-Heuristic.
    Max,
    /// Min-Heuristic.
    Min,
    /// Randomized.
    Random,
    /// Optimus-Greedy (static).
    OptimusStatic,
    /// Optimus-Greedy re-planned every round.
    OptimusDynamic,
}

impl PolicyKind {
    /// All policy kinds, experiment order.
    pub const ALL: [PolicyKind; 7] = [
        PolicyKind::Saturn,
        PolicyKind::CurrentPractice,
        PolicyKind::Max,
        PolicyKind::Min,
        PolicyKind::Random,
        PolicyKind::OptimusStatic,
        PolicyKind::OptimusDynamic,
    ];

    /// Whether this policy re-plans at introspection boundaries.
    pub fn is_dynamic(&self) -> bool {
        matches!(self, PolicyKind::Saturn | PolicyKind::OptimusDynamic)
    }
}

/// A full experiment spec.
#[derive(Debug, Clone)]
pub struct ExperimentSpec {
    /// Workload family.
    pub workload: WorkloadKind,
    /// Cluster spec, e.g. "8", "4x8", "2,2,4,8".
    pub cluster: String,
    /// Policies to compare.
    pub policies: Vec<PolicyKind>,
    /// Trials per policy (paper: 3).
    pub trials: usize,
    /// Runtime-noise sigma.
    pub noise_sigma: f64,
    /// Introspection interval (dynamic policies), seconds.
    pub interval: f64,
    /// Introspection threshold, seconds.
    pub threshold: f64,
    /// Base RNG seed.
    pub seed: u64,
}

impl Default for ExperimentSpec {
    fn default() -> Self {
        Self {
            workload: WorkloadKind::Txt,
            cluster: "8".to_string(),
            policies: PolicyKind::ALL.to_vec(),
            trials: 3,
            noise_sigma: 0.08,
            interval: 1000.0,
            threshold: 500.0,
            seed: 42,
        }
    }
}

impl WorkloadKind {
    /// Config-file tag.
    pub fn tag(&self) -> &'static str {
        match self {
            WorkloadKind::Txt => "txt",
            WorkloadKind::Img => "img",
        }
    }

    /// Parse a config-file tag.
    pub fn from_tag(s: &str) -> anyhow::Result<Self> {
        match s {
            "txt" => Ok(WorkloadKind::Txt),
            "img" => Ok(WorkloadKind::Img),
            other => anyhow::bail!("unknown workload '{other}' (txt|img)"),
        }
    }
}

impl PolicyKind {
    /// Config-file tag (kebab-case, matches the CLI).
    pub fn tag(&self) -> &'static str {
        match self {
            PolicyKind::Saturn => "saturn",
            PolicyKind::CurrentPractice => "current-practice",
            PolicyKind::Max => "max",
            PolicyKind::Min => "min",
            PolicyKind::Random => "random",
            PolicyKind::OptimusStatic => "optimus-static",
            PolicyKind::OptimusDynamic => "optimus-dynamic",
        }
    }

    /// Parse a config-file tag.
    pub fn from_tag(s: &str) -> anyhow::Result<Self> {
        PolicyKind::ALL
            .into_iter()
            .find(|p| p.tag() == s)
            .ok_or_else(|| anyhow::anyhow!("unknown policy '{s}'"))
    }
}

impl ExperimentSpec {
    /// Parse from a JSON file.
    pub fn load(path: &std::path::Path) -> anyhow::Result<Self> {
        Self::from_json(&Json::parse(&std::fs::read_to_string(path)?).map_err(|e| anyhow::anyhow!("{e}"))?)
    }

    /// Persist to a JSON file.
    pub fn save(&self, path: &std::path::Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().pretty())?;
        Ok(())
    }

    /// Lower to a JSON value.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("workload", Json::Str(self.workload.tag().into())),
            ("cluster", Json::Str(self.cluster.clone())),
            ("policies", Json::Arr(self.policies.iter().map(|p| Json::Str(p.tag().into())).collect())),
            ("trials", Json::Num(self.trials as f64)),
            ("noise_sigma", Json::Num(self.noise_sigma)),
            ("interval", Json::Num(self.interval)),
            ("threshold", Json::Num(self.threshold)),
            ("seed", Json::Num(self.seed as f64)),
        ])
    }

    /// Parse from a JSON value, defaulting missing fields.
    pub fn from_json(v: &Json) -> anyhow::Result<Self> {
        let d = Self::default();
        let workload = match v.get("workload").and_then(Json::as_str) {
            Some(s) => WorkloadKind::from_tag(s)?,
            None => d.workload,
        };
        let policies = match v.get("policies").and_then(Json::as_arr) {
            Some(arr) => arr
                .iter()
                .map(|p| {
                    p.as_str()
                        .ok_or_else(|| anyhow::anyhow!("policy must be a string"))
                        .and_then(PolicyKind::from_tag)
                })
                .collect::<anyhow::Result<Vec<_>>>()?,
            None => d.policies.clone(),
        };
        Ok(Self {
            workload,
            cluster: v.get("cluster").and_then(Json::as_str).unwrap_or(&d.cluster).to_string(),
            policies,
            trials: v.get("trials").and_then(Json::as_usize).unwrap_or(d.trials),
            noise_sigma: v.get("noise_sigma").and_then(Json::as_f64).unwrap_or(d.noise_sigma),
            interval: v.get("interval").and_then(Json::as_f64).unwrap_or(d.interval),
            threshold: v.get("threshold").and_then(Json::as_f64).unwrap_or(d.threshold),
            seed: v.get("seed").and_then(Json::as_u64).unwrap_or(d.seed),
        })
    }

    /// Build the cluster from the compact spec.
    pub fn build_cluster(&self) -> anyhow::Result<Cluster> {
        parse_cluster(&self.cluster)
    }

    /// Simulator config for a given policy.
    pub fn sim_config(&self, policy: PolicyKind) -> SimConfig {
        SimConfig {
            noise_sigma: self.noise_sigma,
            introspect: policy
                .is_dynamic()
                .then_some(IntrospectCfg { interval: self.interval, threshold: self.threshold }),
            ..SimConfig::default()
        }
    }
}

/// Parse `"8"` (one node × 8), `"4x8"` (4 nodes × 8), or `"2,2,4,8"`
/// (explicit per-node GPU counts).
pub fn parse_cluster(spec: &str) -> anyhow::Result<Cluster> {
    let s = spec.trim();
    if let Some((n, g)) = s.split_once('x') {
        let n: usize = n.trim().parse()?;
        let g: usize = g.trim().parse()?;
        anyhow::ensure!(n > 0 && g > 0, "cluster spec must be positive");
        return Ok(Cluster::homogeneous(n, g));
    }
    if s.contains(',') {
        let counts: Result<Vec<usize>, _> = s.split(',').map(|c| c.trim().parse()).collect();
        let counts = counts?;
        anyhow::ensure!(counts.iter().all(|&c| c > 0), "GPU counts must be positive");
        return Ok(Cluster::from_gpu_counts(&counts));
    }
    let g: usize = s.parse()?;
    anyhow::ensure!(g > 0, "GPU count must be positive");
    Ok(Cluster::homogeneous(1, g))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_single_node() {
        let c = parse_cluster("8").unwrap();
        assert_eq!(c.nodes.len(), 1);
        assert_eq!(c.total_gpus(), 8);
    }

    #[test]
    fn parse_multi_node() {
        let c = parse_cluster("4x8").unwrap();
        assert_eq!(c.nodes.len(), 4);
        assert_eq!(c.total_gpus(), 32);
    }

    #[test]
    fn parse_heterogeneous() {
        let c = parse_cluster("2, 2, 4, 8").unwrap();
        assert_eq!(c.nodes.len(), 4);
        assert_eq!(c.total_gpus(), 16);
        assert!(!c.is_homogeneous());
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse_cluster("").is_err());
        assert!(parse_cluster("0").is_err());
        assert!(parse_cluster("ax8").is_err());
    }

    #[test]
    fn spec_roundtrip() {
        let spec = ExperimentSpec::default();
        let dir = crate::util::tmp::TempDir::new("config").unwrap();
        let p = dir.path().join("exp.json");
        spec.save(&p).unwrap();
        let back = ExperimentSpec::load(&p).unwrap();
        assert_eq!(back.trials, spec.trials);
        assert_eq!(back.cluster, spec.cluster);
    }

    #[test]
    fn sim_config_dynamic_flag() {
        let spec = ExperimentSpec::default();
        assert!(spec.sim_config(PolicyKind::Saturn).introspect.is_some());
        assert!(spec.sim_config(PolicyKind::Max).introspect.is_none());
        assert!(spec.sim_config(PolicyKind::OptimusDynamic).introspect.is_some());
        assert!(spec.sim_config(PolicyKind::OptimusStatic).introspect.is_none());
    }
}
