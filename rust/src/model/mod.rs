//! DL model descriptors.
//!
//! The SPASE optimizer never inspects weights; it needs the *structural*
//! facts that determine runtime and memory under each parallelism: parameter
//! count, layer count (partitionable stages), per-example FLOPs, and
//! activation footprints. [`ModelDesc`] captures those, with constructors
//! for the paper's evaluated architectures (GPT-2 1.5B, GPT-J 6B, ViT-G
//! 1.8B, ResNet 200M) and for the small transformer LMs the end-to-end
//! example actually trains through the PJRT runtime.


/// Broad architecture family (drives UPP hints, e.g. transformer wrap
/// policies for FSDP, and the model-size sensitivity sweep).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Arch {
    /// Decoder-only transformer LM (GPT family).
    TransformerLm,
    /// Vision transformer.
    VisionTransformer,
    /// Convolutional network (ResNet family).
    ConvNet,
}

/// Structural description of a model, sufficient for cost modeling.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelDesc {
    /// Human-readable name (e.g. "gpt2-1.5b").
    pub name: String,
    /// Architecture family.
    pub arch: Arch,
    /// Total trainable parameters.
    pub params: f64,
    /// Number of partitionable stages/blocks (transformer blocks, ResNet
    /// stages); bounds pipeline partitioning.
    pub layers: usize,
    /// Sequence length for transformer inputs (tokens); 0 for ConvNets.
    pub seq_len: usize,
    /// Forward-pass FLOPs for ONE example (backward is modeled as 2×).
    pub fwd_flops_per_example: f64,
    /// Peak activation bytes for ONE example across the whole model
    /// (without gradient checkpointing).
    pub act_bytes_per_example: f64,
    /// Activation bytes crossing a stage boundary for ONE example (pipeline
    /// p2p traffic per microbatch per boundary).
    pub boundary_act_bytes_per_example: f64,
}

impl ModelDesc {
    /// Decoder-only transformer LM from (layers, hidden width, seq len,
    /// vocab). Parameter count uses the standard 12·L·H² + 2·V·H estimate;
    /// forward FLOPs per token ≈ 2·params.
    pub fn transformer_lm(name: &str, layers: usize, hidden: usize, seq_len: usize, vocab: usize) -> Self {
        let l = layers as f64;
        let h = hidden as f64;
        let v = vocab as f64;
        let params = 12.0 * l * h * h + 2.0 * v * h;
        let tokens = seq_len as f64;
        // 2 FLOPs per param per token, plus attention score term 2·L·S²·H·2.
        let fwd_flops = 2.0 * params * tokens + 4.0 * l * tokens * tokens * h;
        // Activations: ~16·H bytes per token per layer at bf16 with fused attn.
        let act = 16.0 * h * tokens * l * 2.0;
        let boundary = 2.0 * h * tokens; // bf16 hidden states at a stage cut
        Self {
            name: name.to_string(),
            arch: Arch::TransformerLm,
            params,
            layers,
            seq_len,
            fwd_flops_per_example: fwd_flops,
            act_bytes_per_example: act,
            boundary_act_bytes_per_example: boundary,
        }
    }

    /// GPT-2 XL class model (paper TXT workload; 1.5B params).
    pub fn gpt2_1_5b() -> Self {
        Self::transformer_lm("gpt2-1.5b", 48, 1600, 1024, 50257)
    }

    /// GPT-J class model (paper TXT workload; ~6B params).
    pub fn gpt_j_6b() -> Self {
        Self::transformer_lm("gpt-j-6b", 28, 4096, 2048, 50400)
    }

    /// ViT-G class vision transformer (paper IMG workload; ~1.8B params).
    pub fn vit_g_1_8b() -> Self {
        let mut m = Self::transformer_lm("vit-g-1.8b", 48, 1664, 256, 1000);
        m.arch = Arch::VisionTransformer;
        m
    }

    /// Large ResNet (paper IMG workload; ~200M params).
    pub fn resnet_200m() -> Self {
        Self {
            name: "resnet-200m".to_string(),
            arch: Arch::ConvNet,
            params: 2.0e8,
            layers: 16, // residual stage groups usable as pipeline cuts
            seq_len: 0,
            // ~40 GFLOPs fwd per 224² image for a 200M-param ResNet.
            fwd_flops_per_example: 4.0e10,
            act_bytes_per_example: 6.0e8,
            boundary_act_bytes_per_example: 2.0e7,
        }
    }

    /// GPT-2-style model scaled by stacking blocks (paper Fig 8(B) varies
    /// size by stacking transformer encoder blocks, like GPT-3 does).
    pub fn gpt2_stacked(layers: usize) -> Self {
        Self::transformer_lm(&format!("gpt2-stack-{layers}"), layers, 1600, 1024, 50257)
    }

    /// Tiny transformer LM actually trainable through the PJRT CPU runtime
    /// in the e2e example (see `python/compile/model.py` for the matching
    /// JAX definition).
    pub fn tiny_lm(layers: usize, hidden: usize, seq_len: usize, vocab: usize) -> Self {
        Self::transformer_lm(&format!("tiny-lm-l{layers}-h{hidden}"), layers, hidden, seq_len, vocab)
    }

    /// Model-state bytes per parameter for a given optimizer:
    /// bf16 weights + bf16 grads (4 B) plus fp32 master+momentum for SGD
    /// (8 B) or fp32 master+m+v for Adam (12 B). Mirrors the ZeRO paper's
    /// mixed-precision accounting.
    pub fn state_bytes(&self, optimizer: crate::trainer::Optimizer) -> f64 {
        let per_param = match optimizer {
            crate::trainer::Optimizer::Sgd => 12.0,
            crate::trainer::Optimizer::Adam => 16.0,
        };
        self.params * per_param
    }

    /// Parameter bytes at bf16 (communication payloads).
    pub fn param_bytes(&self) -> f64 {
        self.params * 2.0
    }

    /// Total train-step FLOPs for a minibatch of `batch` examples
    /// (forward + backward ≈ 3× forward).
    pub fn step_flops(&self, batch: usize) -> f64 {
        3.0 * self.fwd_flops_per_example * batch as f64
    }

    /// Billions of parameters (display).
    pub fn params_b(&self) -> f64 {
        self.params / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gpt2_param_count_in_range() {
        let m = ModelDesc::gpt2_1_5b();
        assert!(m.params_b() > 1.3 && m.params_b() < 1.8, "{}", m.params_b());
        assert_eq!(m.layers, 48);
    }

    #[test]
    fn gptj_param_count_in_range() {
        let m = ModelDesc::gpt_j_6b();
        assert!(m.params_b() > 5.0 && m.params_b() < 7.0, "{}", m.params_b());
    }

    #[test]
    fn vit_param_count_in_range() {
        let m = ModelDesc::vit_g_1_8b();
        assert!(m.params_b() > 1.4 && m.params_b() < 2.2, "{}", m.params_b());
        assert_eq!(m.arch, Arch::VisionTransformer);
    }

    #[test]
    fn resnet_is_smallest() {
        let r = ModelDesc::resnet_200m();
        assert!(r.params < ModelDesc::vit_g_1_8b().params);
        assert_eq!(r.arch, Arch::ConvNet);
    }

    #[test]
    fn step_flops_scales_with_batch() {
        let m = ModelDesc::gpt2_1_5b();
        assert!((m.step_flops(32) / m.step_flops(16) - 2.0).abs() < 1e-12);
        // fwd+bwd = 3x fwd
        assert!((m.step_flops(1) / m.fwd_flops_per_example - 3.0).abs() < 1e-12);
    }

    #[test]
    fn stacked_models_grow_linearly_in_blocks() {
        let a = ModelDesc::gpt2_stacked(24);
        let b = ModelDesc::gpt2_stacked(48);
        // embedding term is shared, block term doubles
        assert!(b.params > a.params * 1.6 && b.params < a.params * 2.0);
    }

    #[test]
    fn state_bytes_by_optimizer() {
        let m = ModelDesc::gpt2_1_5b();
        let sgd = m.state_bytes(crate::trainer::Optimizer::Sgd);
        let adam = m.state_bytes(crate::trainer::Optimizer::Adam);
        assert!(adam > sgd);
        assert!((adam / m.params - 16.0).abs() < 1e-12);
    }

    #[test]
    fn gptj_needs_multiple_a100s() {
        // The paper's premise: GPT-J 6B OOMs a single 40 GiB A100 under Adam.
        let m = ModelDesc::gpt_j_6b();
        let gib = m.state_bytes(crate::trainer::Optimizer::Adam) / (1024f64.powi(3));
        assert!(gib > 40.0, "{gib}");
    }
}
