//! Online job submission: the event-driven coordinator for streaming
//! model-selection workloads.
//!
//! The paper's SPASE setting (§4.1) assumes every job exists at t = 0;
//! its stated follow-on direction is "ways to support online job
//! submissions" (the Hydra lineage of multi-model scheduling). This
//! module provides that path:
//!
//! - users [`OnlineCoordinator::submit`] tasks carrying an
//!   [`crate::trainer::Task::arrival`] time (builders in
//!   [`crate::trainer::workloads`] generate Poisson / burst / batch
//!   traces);
//! - a pending-job queue holds not-yet-arrived submissions;
//! - [`OnlineCoordinator::run`] profiles the stream and drives the
//!   arrival-aware simulator: each arrival event injects its tasks and
//!   triggers the same re-plan path introspection rounds use;
//! - the planner defaults to the **incremental re-solve** mode of
//!   [`JointOptimizer`]: warm-started from the current incumbent plan,
//!   re-deciding only new and not-yet-started tasks instead of solving
//!   the full MILP from scratch on every arrival (see
//!   [`JointOptimizer::resolve_incremental`] and `benches/bench_online.rs`
//!   for the warm-vs-cold latency comparison);
//! - cluster capacity is a failure-prone, elastic resource:
//!   [`OnlineCoordinator::inject_event`] queues crashes, joins, drains,
//!   and stragglers ([`crate::cluster::ClusterEvent`]) that cut running
//!   segments exactly like arrivals do, and the report's robustness
//!   fields ([`OnlineStats::failures`], [`OnlineStats::relocations`],
//!   [`OnlineStats::lost_work_secs`], [`OnlineStats::time_to_recover`])
//!   account for what each outage cost.
//!
//! This module is on the panic-sensitive path (see `LINTS.md`): it
//! fronts long-running submission streams, so non-test code must stay
//! panic-free — `saturn-lint` and the deny attributes below both
//! enforce it.

#![deny(clippy::unwrap_used, clippy::expect_used)]

use crate::cluster::{estimate_reliability, Cluster, ClusterEvent, NodeReliability, TimedClusterEvent};
use crate::costmodel::CostModel;
use crate::metrics::{online_stats, OnlineStats};
use crate::parallelism::UppRegistry;
use crate::profiler::{ProfileGrid, TrialRunner};
use crate::sim::{simulate, IntrospectCfg, SimConfig, SimResult};
use crate::solver::joint::JointOptimizer;
use crate::trainer::{Task, Workload};
use crate::util::rng::DetRng;
use std::sync::Arc;

/// Outcome of draining an online submission stream.
#[derive(Debug, Clone)]
pub struct OnlineReport {
    /// Raw simulation result (spans, completions, starts, events).
    pub result: SimResult,
    /// Queueing-delay / turnaround statistics.
    pub stats: OnlineStats,
    /// The executed workload in arrival order (ids as assigned at submit).
    pub workload: Workload,
    /// Trial Runner output for the stream.
    pub grid: ProfileGrid,
    /// Simulated profiling overhead, seconds.
    pub profile_overhead_secs: f64,
}

/// Event-driven coordinator for online job submission.
pub struct OnlineCoordinator {
    /// The cluster being scheduled onto.
    pub cluster: Cluster,
    /// Parallelism library used to profile submissions.
    pub registry: UppRegistry,
    /// Planner invoked at every arrival/introspection event. Defaults to
    /// the incremental (warm-start) joint optimizer. Tune
    /// [`JointOptimizer::warm_frac`] here to trade per-arrival re-solve
    /// latency against plan quality (the default grants a re-solve a
    /// quarter of the cold budget; a smaller fraction truncates the
    /// anneal earlier and can change the plan — that trade is the knob's
    /// purpose), and [`JointOptimizer::threads`] to pick the speculative
    /// engine's parallelism, which never changes the trajectory — at any
    /// fixed budget the search path is bit-identical across thread
    /// counts.
    ///
    /// **Preemption × warm budget.** Turn on checkpoint-and-shrink of
    /// in-flight gangs via [`SimConfig::preempt`] on [`Self::sim`] — the
    /// simulator then hands the re-solver a churn cost equal to its
    /// `switch_cost` ([`JointOptimizer::preempt`] is the same knob for
    /// driving the solver outside a simulation; the context's value
    /// wins). Preemption widens the incremental search space from "new +
    /// not-yet-started" to *every* live task, so at a fixed
    /// [`JointOptimizer::warm_frac`] each re-solve spreads its budget
    /// over more decisions; streams that enable `preempt` under tight
    /// arrival rates usually want a correspondingly larger `warm_frac`
    /// (or more `threads`) so the anneal still converges before the
    /// budget truncates it. With `preempt` off the re-solve trajectory is
    /// bit-identical to the historical pinning behavior.
    ///
    /// **Objective.** Set [`SimConfig::objective`] on [`Self::sim`] to
    /// optimize an SLO-aware scalar — mean/weighted turnaround or the
    /// p95 tail surrogate — instead of makespan: the simulator threads
    /// it into every planning context (where it wins over
    /// [`JointOptimizer::objective`], exactly like the preemption cost)
    /// and compares re-plan proposals on the same scalar, so the planner
    /// and the acceptance threshold never optimize different quantities.
    /// The stream's report surfaces the matching tail metrics
    /// ([`OnlineStats::p95_queueing_delay`] /
    /// [`OnlineStats::p95_turnaround`]).
    pub optimizer: JointOptimizer,
    /// Simulation knobs; introspection defaults on (the online path
    /// shares its re-plan machinery). [`SimConfig::preempt`] and
    /// [`SimConfig::objective`] live here — see [`Self::optimizer`] for
    /// how they interact with the solver knobs.
    pub sim: SimConfig,
    queue: Vec<Task>,
    next_id: usize,
}

impl OnlineCoordinator {
    /// Coordinator over a cluster with the default parallelism library
    /// and the incremental joint optimizer.
    pub fn new(cluster: Cluster) -> Self {
        Self {
            registry: UppRegistry::default_library(Arc::new(CostModel::default())),
            cluster,
            optimizer: JointOptimizer::incremental(),
            sim: SimConfig { introspect: Some(IntrospectCfg::default()), ..SimConfig::default() },
            queue: Vec::new(),
            next_id: 0,
        }
    }

    /// Submit one task to the pending queue. Ids are reassigned in
    /// submission order (the stream owns identity); returns the id.
    pub fn submit(&mut self, mut task: Task) -> usize {
        let id = self.next_id;
        self.next_id += 1;
        task.id = id;
        self.queue.push(task);
        id
    }

    /// Submit a batch of tasks; returns their assigned ids.
    pub fn submit_all<I: IntoIterator<Item = Task>>(&mut self, tasks: I) -> Vec<usize> {
        tasks.into_iter().map(|t| self.submit(t)).collect()
    }

    /// Inject one cluster capacity event (crash, elastic join/leave,
    /// straggler) into the stream at an absolute time. Events ride the
    /// same re-plan pipeline as arrivals and introspection rounds; the
    /// report's [`OnlineStats`] carries the resulting robustness
    /// accounting (failures, relocations, lost work, recovery latency).
    /// Trace builders live in [`crate::trainer::workloads`]
    /// (`poisson_failure_events`, `rack_failure_events`,
    /// `spot_churn_events`, `straggler_events`). Junk events (non-finite
    /// or negative times, unknown nodes, non-positive straggler rates,
    /// negative drain windows) are rejected with a descriptive error at
    /// the API edge — never panicked on, and never silently dropped deep
    /// in the simulator.
    pub fn inject_event(&mut self, event: TimedClusterEvent) -> anyhow::Result<()> {
        validate_event(&event, self.cluster.nodes.len())?;
        self.sim.chaos.push(event);
        Ok(())
    }

    /// Inject a batch of cluster capacity events (e.g. a generated
    /// failure trace). Order does not matter; the simulator applies
    /// events in time order. Validation is all-or-nothing: the first
    /// junk event rejects the whole batch and leaves the queued chaos
    /// trace untouched.
    pub fn inject_events<I: IntoIterator<Item = TimedClusterEvent>>(
        &mut self,
        events: I,
    ) -> anyhow::Result<()> {
        let events: Vec<TimedClusterEvent> = events.into_iter().collect();
        for e in &events {
            validate_event(e, self.cluster.nodes.len())?;
        }
        self.sim.chaos.extend(events);
        Ok(())
    }

    /// Install a per-node reliability model ([`NodeReliability`]) for the
    /// stream: the planner prices expected lost work + restarts into
    /// every placement, and the simulator's rollback accounting follows
    /// each task's checkpoint cadence (explicit
    /// [`crate::trainer::Task::ckpt_interval`], else the host node's
    /// Young/Daly optimum from [`SimConfig::ckpt_cost`]). One entry per
    /// node; `None` entries keep that node risk-blind. Rejects length
    /// mismatches and non-finite/negative statistics at the API edge.
    pub fn set_reliability(
        &mut self,
        reliability: Vec<Option<NodeReliability>>,
    ) -> anyhow::Result<()> {
        anyhow::ensure!(
            reliability.len() == self.cluster.nodes.len(),
            "reliability has {} entries but the cluster has {} nodes",
            reliability.len(),
            self.cluster.nodes.len()
        );
        for (node, rel) in reliability.iter().enumerate() {
            if let Some(r) = rel {
                anyhow::ensure!(
                    !r.mtbf_secs.is_nan() && r.mtbf_secs > 0.0,
                    "node {node}: MTBF must be positive (∞ = never fails), got {}",
                    r.mtbf_secs
                );
                anyhow::ensure!(
                    r.restart_secs.is_finite() && r.restart_secs >= 0.0,
                    "node {node}: restart delay must be finite and non-negative, got {}",
                    r.restart_secs
                );
            }
        }
        self.sim.reliability = reliability;
        Ok(())
    }

    /// Fit the reliability model from the chaos trace queued so far
    /// (fail→join gaps per node over `horizon` seconds, via
    /// [`estimate_reliability`]) and install it with the same validation
    /// as [`Self::set_reliability`]. Returns the fitted model.
    pub fn learn_reliability(
        &mut self,
        horizon: f64,
    ) -> anyhow::Result<Vec<Option<NodeReliability>>> {
        anyhow::ensure!(
            horizon.is_finite() && horizon > 0.0,
            "horizon must be finite and positive, got {horizon}"
        );
        let fitted = estimate_reliability(&self.sim.chaos, self.cluster.nodes.len(), horizon);
        self.set_reliability(fitted.clone())?;
        Ok(fitted)
    }
}

/// Edge validation for one chaos event: finite non-negative time, a node
/// the cluster actually has, a finite positive straggler rate, a finite
/// non-negative drain window. Pure and panic-free.
fn validate_event(event: &TimedClusterEvent, n_nodes: usize) -> anyhow::Result<()> {
    anyhow::ensure!(
        event.at.is_finite() && event.at >= 0.0,
        "event time must be finite and non-negative, got {}",
        event.at
    );
    let node = match event.event {
        ClusterEvent::NodeFail { node }
        | ClusterEvent::NodeJoin { node }
        | ClusterEvent::NodeLeave { node, .. }
        | ClusterEvent::SlowdownStart { node, .. }
        | ClusterEvent::SlowdownEnd { node } => node,
    };
    anyhow::ensure!(node < n_nodes, "event names node {node} but the cluster has {n_nodes} nodes");
    match event.event {
        ClusterEvent::SlowdownStart { rate, .. } => anyhow::ensure!(
            rate.is_finite() && rate > 0.0,
            "straggler rate must be finite and positive, got {rate}"
        ),
        ClusterEvent::NodeLeave { grace, .. } => anyhow::ensure!(
            grace.is_finite() && grace >= 0.0,
            "drain grace must be finite and non-negative, got {grace}"
        ),
        _ => {}
    }
    Ok(())
}

impl OnlineCoordinator {
    /// Tasks waiting in the pending queue.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Drain the pending queue: profile every submission, then execute
    /// the stream on the arrival-aware simulator. Tasks are injected at
    /// their arrival events; each event re-plans through the incremental
    /// re-solver. The queue is empty afterwards; later submissions start
    /// a fresh stream.
    pub fn run(&mut self, seed: u64) -> OnlineReport {
        let mut workload: Workload = std::mem::take(&mut self.queue);
        workload.sort_by(|a, b| a.arrival.total_cmp(&b.arrival).then(a.id.cmp(&b.id)));
        let runner = TrialRunner::new(self.registry.clone());
        let (grid, profile_overhead_secs) = runner.profile(&workload, &self.cluster);
        let mut rng = DetRng::new(seed);
        let result =
            simulate(&self.optimizer, &workload, &grid, &self.cluster, self.sim.clone(), &mut rng);
        let stats = online_stats(&workload, &result);
        OnlineReport { result, stats, workload, grid, profile_overhead_secs }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::model::ModelDesc;
    use crate::trainer::{HParams, Optimizer};

    fn small_task(arrival: f64) -> Task {
        Task::new(0, ModelDesc::resnet_200m(), HParams::new(32, 1e-4, 1, Optimizer::Sgd), 640)
            .with_arrival(arrival)
    }

    #[test]
    fn submit_assigns_stream_ids() {
        let mut oc = OnlineCoordinator::new(Cluster::single_node_8gpu());
        let a = oc.submit(small_task(0.0));
        let b = oc.submit(small_task(10.0));
        assert_eq!((a, b), (0, 1));
        assert_eq!(oc.pending(), 2);
        let more = oc.submit_all(vec![small_task(20.0), small_task(30.0)]);
        assert_eq!(more, vec![2, 3]);
        assert_eq!(oc.pending(), 4);
    }

    #[test]
    fn run_drains_queue_and_completes_everything() {
        let mut oc = OnlineCoordinator::new(Cluster::single_node_8gpu());
        for i in 0..6 {
            oc.submit(small_task(i as f64 * 5.0));
        }
        let report = oc.run(7);
        assert_eq!(oc.pending(), 0);
        assert_eq!(report.result.completions.len(), 6);
        assert_eq!(report.stats.finished, 6);
        assert!(report.result.makespan > 0.0);
        assert!(report.profile_overhead_secs > 0.0);
        // no task may start before its submission
        for t in &report.workload {
            let (_, start) =
                report.result.starts.iter().find(|(id, _)| *id == t.id).unwrap();
            assert!(*start >= t.arrival - 1e-6, "task {} jumped its arrival", t.id);
        }
        // later-arriving tasks really were injected as events
        assert!(report.result.arrival_events > 0);
    }

    #[test]
    fn run_is_deterministic_per_seed() {
        let mk = || {
            let mut oc = OnlineCoordinator::new(Cluster::single_node_8gpu());
            // a timeout the solver never hits in-test, so both runs anneal
            // the exact same number of iterations (wall-clock independent)
            oc.optimizer.timeout = std::time::Duration::from_secs(120);
            for i in 0..4 {
                oc.submit(small_task(i as f64 * 3.0));
            }
            oc.run(11).result.makespan
        };
        assert_eq!(mk(), mk());
    }

    /// The coordinator can tune the incremental warm budget (satellite:
    /// `timeout / 4` used to be hardcoded). The fraction only moves the
    /// wall-clock cap, so with an un-truncatable timeout any fraction
    /// executes the identical stream.
    #[test]
    fn warm_budget_tunable_without_changing_plans() {
        let run_with = |frac: f64| {
            let mut oc = OnlineCoordinator::new(Cluster::single_node_8gpu());
            oc.optimizer.timeout = std::time::Duration::from_secs(240);
            oc.optimizer.warm_frac = frac;
            for i in 0..4 {
                oc.submit(small_task(i as f64 * 400.0));
            }
            oc.run(13).result
        };
        let quarter = run_with(0.25);
        let half = run_with(0.5);
        assert_eq!(quarter, half, "untruncated budgets must yield identical streams");
        assert_eq!(quarter.completions.len(), 4);
    }

    /// The preemption knob is surfaced through the coordinator's
    /// `SimConfig`: streams run deterministically with it on, every task
    /// still completes at or after its arrival, and with it off the
    /// stream is byte-identical to the default configuration (which IS
    /// preempt-off — pinning unchanged).
    #[test]
    fn preempt_knob_surfaced_and_off_by_default() {
        let run_with = |preempt: bool| {
            let mut oc = OnlineCoordinator::new(Cluster::single_node_8gpu());
            oc.optimizer.timeout = std::time::Duration::from_secs(240);
            assert!(!oc.sim.preempt, "preemption must default off");
            oc.sim.preempt = preempt;
            for i in 0..5 {
                oc.submit(small_task(i as f64 * 300.0));
            }
            oc.run(17)
        };
        let off = run_with(false);
        let off2 = run_with(false);
        assert_eq!(off.result, off2.result, "preempt-off stream must be deterministic");
        assert_eq!(off.result.preemptions, 0, "no preemptions while pinning");
        assert_eq!(off.stats.preemptions, 0);
        let on = run_with(true);
        let on2 = run_with(true);
        assert_eq!(on.result, on2.result, "preempt-on stream must be deterministic");
        assert_eq!(on.result.completions.len(), 5);
        for t in &on.workload {
            let (_, start) = on.result.starts.iter().find(|(id, _)| *id == t.id).unwrap();
            assert!(*start >= t.arrival - 1e-6, "task {} jumped its arrival", t.id);
        }
        assert_eq!(on.stats.preemptions, on.result.preemptions);
    }

    /// Chaos events are surfaced through the coordinator: a crash/repair
    /// pair mid-stream runs deterministically, every task still
    /// completes, the failure is accounted, and the report's stats mirror
    /// the simulation's robustness fields. A no-event stream stays
    /// byte-identical to the pre-chaos coordinator.
    #[test]
    fn chaos_events_surfaced_and_deterministic() {
        use crate::cluster::{ClusterEvent, TimedClusterEvent};
        let run_with = |fail: bool| {
            let mut oc = OnlineCoordinator::new(Cluster::single_node_8gpu());
            oc.optimizer.timeout = std::time::Duration::from_secs(240);
            assert!(oc.sim.chaos.is_empty(), "chaos must default empty");
            if fail {
                oc.inject_event(TimedClusterEvent {
                    at: 50.0,
                    event: ClusterEvent::NodeFail { node: 0 },
                })
                .unwrap();
                oc.inject_events(vec![TimedClusterEvent {
                    at: 400.0,
                    event: ClusterEvent::NodeJoin { node: 0 },
                }])
                .unwrap();
            }
            for i in 0..5 {
                oc.submit(small_task(i as f64 * 300.0));
            }
            oc.run(23)
        };
        let calm = run_with(false);
        assert_eq!(calm.result.failures, 0);
        assert!(calm.result.capacity_trace.is_empty(), "no chaos ⇒ no capacity trace");
        let a = run_with(true);
        let b = run_with(true);
        assert_eq!(a.result, b.result, "chaos stream must be deterministic");
        assert_eq!(a.result.completions.len(), 5, "the repaired node finishes the stream");
        assert_eq!(a.result.failures, 1);
        assert_eq!(a.result.capacity_trace.first(), Some(&(0.0, 8)));
        assert!(a.result.capacity_trace.contains(&(50.0, 0)), "the crash empties the cluster");
        // stats mirror the simulation's robustness accounting
        assert_eq!(a.stats.failures, a.result.failures);
        assert_eq!(a.stats.relocations, a.result.relocations);
        assert_eq!(a.stats.lost_work_secs, a.result.lost_work_secs);
        assert_eq!(a.stats.time_to_recover, a.result.time_to_recover);
        for t in &a.workload {
            let (_, start) = a.result.starts.iter().find(|(id, _)| *id == t.id).unwrap();
            assert!(*start >= t.arrival - 1e-6, "task {} jumped its arrival", t.id);
        }
    }

    /// The objective knob is surfaced through the coordinator's
    /// `SimConfig`: it defaults to makespan, a turnaround stream runs
    /// deterministically with every arrival respected, and the report
    /// carries the new p95 statistics.
    #[test]
    fn objective_knob_surfaced_and_defaults_to_makespan() {
        let run_with = |objective: crate::solver::Objective| {
            let mut oc = OnlineCoordinator::new(Cluster::single_node_8gpu());
            oc.optimizer.timeout = std::time::Duration::from_secs(240);
            assert!(oc.sim.objective.is_makespan(), "objective must default to makespan");
            oc.sim.objective = objective;
            for i in 0..5 {
                oc.submit(small_task(i as f64 * 300.0));
            }
            oc.run(19)
        };
        let turn = run_with(crate::solver::Objective::MeanTurnaround);
        let turn2 = run_with(crate::solver::Objective::MeanTurnaround);
        assert_eq!(turn.result, turn2.result, "turnaround stream must be deterministic");
        assert_eq!(turn.result.completions.len(), 5);
        for t in &turn.workload {
            let (_, start) = turn.result.starts.iter().find(|(id, _)| *id == t.id).unwrap();
            assert!(*start >= t.arrival - 1e-6, "task {} jumped its arrival", t.id);
        }
        // the p95 fields are populated and ordered sanely
        assert!(turn.stats.p95_turnaround >= turn.stats.mean_turnaround - 1e-9);
        assert!(turn.stats.p95_turnaround <= turn.stats.max_turnaround + 1e-9);
        assert!(turn.stats.p95_queueing_delay <= turn.stats.max_queue_delay + 1e-9);
    }

    /// Satellite: the event-ingest boundary returns errors instead of
    /// panicking (or silently dropping) on junk — non-finite/negative
    /// times, unknown nodes, non-positive straggler rates, bad drain
    /// windows — and a rejected batch leaves the queued trace untouched.
    #[test]
    fn junk_events_rejected_without_panic() {
        let ev = |at: f64, event: ClusterEvent| TimedClusterEvent { at, event };
        let fail = |node| ClusterEvent::NodeFail { node };
        let mut oc = OnlineCoordinator::new(Cluster::single_node_8gpu());
        let junk = [
            ev(f64::NAN, fail(0)),
            ev(f64::INFINITY, fail(0)),
            ev(-1.0, fail(0)),
            ev(10.0, fail(7)), // single-node cluster: node 7 does not exist
            ev(10.0, ClusterEvent::SlowdownStart { node: 0, rate: 0.0 }),
            ev(10.0, ClusterEvent::SlowdownStart { node: 0, rate: -0.5 }),
            ev(10.0, ClusterEvent::SlowdownStart { node: 0, rate: f64::NAN }),
            ev(10.0, ClusterEvent::NodeLeave { node: 0, grace: -1.0 }),
            ev(10.0, ClusterEvent::NodeLeave { node: 0, grace: f64::INFINITY }),
        ];
        for e in &junk {
            let err = oc.inject_event(e.clone()).unwrap_err();
            assert!(!err.to_string().is_empty());
        }
        assert!(oc.sim.chaos.is_empty(), "rejected events must not be queued");
        // all-or-nothing batches: one bad event poisons the whole batch
        let batch = vec![ev(5.0, fail(0)), ev(f64::NAN, fail(0))];
        assert!(oc.inject_events(batch).is_err());
        assert!(oc.sim.chaos.is_empty(), "a rejected batch must leave the trace untouched");
        // and a clean event still goes through
        oc.inject_event(ev(5.0, fail(0))).unwrap();
        oc.inject_events(vec![ev(9.0, ClusterEvent::NodeJoin { node: 0 })]).unwrap();
        assert_eq!(oc.sim.chaos.len(), 2);
    }

    /// The reliability model is surfaced through the coordinator with
    /// the same edge validation as event ingest, and can be fitted from
    /// the queued chaos trace (fail→join gaps over a horizon).
    #[test]
    fn reliability_surfaced_and_learned_from_trace() {
        let mut oc = OnlineCoordinator::new(Cluster::single_node_8gpu());
        assert!(oc.sim.reliability.is_empty(), "reliability must default off");
        // wrong length and junk statistics are rejected, state untouched
        assert!(oc.set_reliability(vec![None, None]).is_err());
        assert!(oc.set_reliability(vec![Some(NodeReliability::new(f64::NAN, 0.0))]).is_err());
        assert!(oc.set_reliability(vec![Some(NodeReliability::new(0.0, 0.0))]).is_err());
        assert!(oc.set_reliability(vec![Some(NodeReliability::new(800.0, -1.0))]).is_err());
        assert!(oc
            .set_reliability(vec![Some(NodeReliability::new(800.0, f64::INFINITY))])
            .is_err());
        assert!(oc.sim.reliability.is_empty());
        // an infinite MTBF is a legal "never fails" model
        oc.set_reliability(vec![Some(NodeReliability::reliable())]).unwrap();
        // fitting: one 200 s outage at t=100 over a 1000 s horizon
        oc.inject_event(TimedClusterEvent { at: 100.0, event: ClusterEvent::NodeFail { node: 0 } })
            .unwrap();
        oc.inject_event(TimedClusterEvent { at: 300.0, event: ClusterEvent::NodeJoin { node: 0 } })
            .unwrap();
        assert!(oc.learn_reliability(f64::NAN).is_err());
        assert!(oc.learn_reliability(-5.0).is_err());
        let fitted = oc.learn_reliability(1000.0).unwrap();
        let r = fitted[0].expect("the failing node carries a model");
        assert_eq!(r.mtbf_secs, 800.0, "uptime 100 + 700 over one failure");
        assert_eq!(r.restart_secs, 200.0, "one 200 s outage");
        assert_eq!(oc.sim.reliability, fitted, "the fit is installed on the stream");
    }
}
