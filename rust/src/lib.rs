//! # Saturn — an optimized data system for multi-large-model DL workloads
//!
//! Reproduction of *"Saturn: An Optimized Data System for Multi-Large-Model
//! Deep Learning Workloads"* (Nagrecha & Kumar, 2023) as a three-layer
//! Rust + JAX + Pallas stack.
//!
//! Saturn tackles the joint **SPASE** problem: **S**elect a **Pa**rallelism
//! for each model, apportion GPU**s**, and schedul**E** all jobs on a fixed
//! cluster so end-to-end makespan is minimized. The crate provides:
//!
//! - [`cluster`] — node/GPU/DRAM topology descriptions.
//! - [`model`] — DL model descriptors (parameter counts, FLOPs, activations).
//! - [`trainer`] — the user-facing `Task`/`HParams` API and workload builders.
//! - [`parallelism`] — the UPP (User-Pluggable Parallelism) abstraction and
//!   the default library: DDP, FSDP, GPipe-style pipelining, and spilling.
//! - [`costmodel`] — calibrated analytic per-minibatch runtime/memory models.
//! - [`profiler`] — the Trial Runner: plan enumeration + runtime estimation.
//! - [`solver`] — the SPASE joint optimizer: simplex LP, branch-and-bound
//!   MILP (paper eqs. 1–11), and the anytime incumbent search used under a
//!   wall-clock timeout — a speculative parallel annealing engine whose
//!   trajectories are bit-identical for every thread count, scoring
//!   candidates under pluggable objectives (makespan by default;
//!   mean/weighted turnaround and a smoothed-p95 tail surrogate for
//!   SLO-aware online streams).
//! - [`sched`] — execution-plan representation and validity checking.
//! - [`baselines`] — Max/Min heuristics, Optimus-Greedy, Randomized, and the
//!   dynamic Optimus variants from the paper's evaluation.
//! - [`introspect`] — the round-based introspective re-solver (paper Alg. 2).
//! - [`online`] — online job submission (the paper's stated follow-on):
//!   an event-driven coordinator with a pending-job queue; tasks carry an
//!   `arrival` time, arrival events inject them mid-run, and the joint
//!   optimizer's *incremental* mode warm-starts each re-solve from the
//!   incumbent plan instead of solving from scratch.
//! - [`sim`] — a discrete-event cluster simulator that executes plans,
//!   models checkpoint/restart costs, records utilization traces, and
//!   cuts segments at both introspection and arrival events.
//! - [`runtime`] — PJRT runtime: loads AOT-compiled HLO artifacts (produced
//!   by the build-time JAX/Pallas layer) and executes them from Rust.
//! - [`exec`] — the real executor: tokio-based gang launch over emulated
//!   device slots, driving actual training steps through [`runtime`].
//! - [`metrics`] — utilization sampling and report generation.
//! - [`lint`] — `saturn-lint`, the dependency-free static analyzer that
//!   enforces the determinism and panic-freedom contracts at CI time.
//!
//! Python (JAX + Pallas) appears only at build time under `python/compile/`;
//! the Rust binary is self-contained once `artifacts/` is built.

pub mod baselines;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod costmodel;
pub mod exec;
pub mod introspect;
pub mod lint;
pub mod metrics;
pub mod model;
pub mod online;
pub mod parallelism;
pub mod profiler;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod solver;
pub mod trainer;
pub mod util;

pub use cluster::Cluster;
pub use online::OnlineCoordinator;
pub use profiler::{ProfileGrid, TrialRunner};
pub use sched::Schedule;
pub use solver::joint::JointOptimizer;
pub use trainer::{HParams, Task, Workload};
