//! `saturn` — CLI for the Saturn SPASE system.
//!
//! Subcommands:
//! - `profile`    — run the Trial Runner on a workload and dump the grid;
//! - `plan`       — produce a one-shot execution plan (table output);
//! - `simulate`   — compare policies on the simulated cluster;
//! - `experiment` — run a JSON [`saturn::config::ExperimentSpec`];
//! - `artifacts`  — verify the AOT artifacts load and compile.
//!
//! Flag parsing is hand-rolled (no CLI crate is vendored offline):
//! `--key value` or `--key=value` pairs after the subcommand.

use saturn::baselines::{CurrentPractice, MaxHeuristic, MinHeuristic, OptimusGreedy, Randomized};
use saturn::config::{parse_cluster, ExperimentSpec, PolicyKind, WorkloadKind};
use saturn::coordinator::Saturn;
use saturn::metrics::{reduction_pct, trial_stats};
use saturn::sim::simulate;
use saturn::solver::joint::JointOptimizer;
use saturn::solver::policy::Policy;
use saturn::trainer::{workloads, Workload};
use saturn::util::rng::DetRng;
use saturn::util::table::TextTable;
use std::collections::HashMap;

/// Minimal `--key value` / `--key=value` argument map.
struct Args {
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Self, String> {
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            let Some(key) = a.strip_prefix("--") else {
                return Err(format!("unexpected argument '{a}'"));
            };
            if let Some((k, v)) = key.split_once('=') {
                flags.insert(k.to_string(), v.to_string());
            } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                flags.insert(key.to_string(), argv[i + 1].clone());
                i += 1;
            } else {
                flags.insert(key.to_string(), "true".to_string()); // boolean flag
            }
            i += 1;
        }
        Ok(Self { flags })
    }

    fn get(&self, key: &str, default: &str) -> String {
        self.flags.get(key).cloned().unwrap_or_else(|| default.to_string())
    }

    fn get_u64(&self, key: &str, default: u64) -> anyhow::Result<u64> {
        match self.flags.get(key) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    fn get_usize(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        match self.flags.get(key) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

const USAGE: &str = "saturn — joint parallelism selection, GPU apportionment, and scheduling\n\
\n\
USAGE: saturn <command> [--flags]\n\
\n\
COMMANDS:\n\
  profile    --workload txt|img --cluster 8|4x8|2,2,4,8\n\
  plan       --workload txt|img --cluster SPEC --seed N --timeout-ms N\n\
  simulate   --workload txt|img --cluster SPEC --seed N --trials N\n\
  experiment [--config exp.json] [--emit-default]\n\
  artifacts  [--dir artifacts]\n";

fn build_workload(name: &str) -> Workload {
    match name {
        "img" => workloads::img_workload(),
        _ => workloads::txt_workload(),
    }
}

fn policy_of(kind: PolicyKind) -> Box<dyn Policy> {
    match kind {
        PolicyKind::Saturn => Box::new(JointOptimizer::default()),
        PolicyKind::CurrentPractice => Box::new(CurrentPractice),
        PolicyKind::Max => Box::new(MaxHeuristic),
        PolicyKind::Min => Box::new(MinHeuristic),
        PolicyKind::Random => Box::new(Randomized),
        PolicyKind::OptimusStatic | PolicyKind::OptimusDynamic => Box::new(OptimusGreedy),
    }
}

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        eprint!("{USAGE}");
        std::process::exit(2);
    };
    let args = Args::parse(&argv[1..]).map_err(|e| anyhow::anyhow!(e))?;
    match cmd.as_str() {
        "profile" => cmd_profile(&args),
        "plan" => cmd_plan(&args),
        "simulate" => cmd_simulate(&args),
        "experiment" => cmd_experiment(&args),
        "artifacts" => cmd_artifacts(&args),
        other => {
            eprintln!("unknown command '{other}'\n");
            eprint!("{USAGE}");
            std::process::exit(2);
        }
    }
}

fn cmd_profile(args: &Args) -> anyhow::Result<()> {
    let w = build_workload(&args.get("workload", "txt"));
    let c = parse_cluster(&args.get("cluster", "8"))?;
    let mut saturn = Saturn::new(c);
    let overhead = saturn.profile(&w);
    let grid = saturn.grid.as_ref().unwrap();
    let mut t = TextTable::new(vec!["task", "parallelism", "gpus", "knobs", "s/minibatch"]);
    for task in &w {
        for cfg in grid.configs(task) {
            t.row(vec![
                task.name.clone(),
                cfg.upp.clone(),
                cfg.gpus.to_string(),
                cfg.knobs.summary(cfg.kind),
                format!("{:.3}", cfg.minibatch_secs),
            ]);
        }
    }
    println!("{}", t.render());
    println!("profiled {} plans; simulated profiling overhead: {:.0}s", grid.len(), overhead);
    Ok(())
}

fn cmd_plan(args: &Args) -> anyhow::Result<()> {
    let w = build_workload(&args.get("workload", "txt"));
    let c = parse_cluster(&args.get("cluster", "8"))?;
    let seed = args.get_u64("seed", 42)?;
    let timeout_ms = args.get_u64("timeout-ms", 500)?;
    let mut saturn = Saturn::new(c);
    saturn.optimizer = JointOptimizer::with_timeout(std::time::Duration::from_millis(timeout_ms));
    saturn.profile(&w);
    let plan = saturn.plan(&w, seed)?;
    plan.validate(&saturn.cluster, &w).map_err(|e| anyhow::anyhow!(e))?;
    let mut t = TextTable::new(vec!["task", "parallelism", "gpus", "node", "start", "duration"]);
    let mut rows: Vec<_> = plan.assignments.iter().collect();
    rows.sort_by(|a, b| a.start.total_cmp(&b.start));
    for a in rows {
        let task = w.iter().find(|t| t.id == a.task_id).unwrap();
        t.row(vec![
            task.name.clone(),
            a.config.upp.clone(),
            a.config.gpus.to_string(),
            a.node.to_string(),
            format!("{:.0}s", a.start),
            format!("{:.0}s", a.duration),
        ]);
    }
    println!("{}", t.render());
    println!(
        "makespan: {} (utilization {:.1}%)",
        saturn::util::fmt_hms(plan.makespan()),
        100.0 * plan.utilization(&saturn.cluster)
    );
    Ok(())
}

fn cmd_simulate(args: &Args) -> anyhow::Result<()> {
    let w = build_workload(&args.get("workload", "txt"));
    let c = parse_cluster(&args.get("cluster", "8"))?;
    let seed = args.get_u64("seed", 42)?;
    let trials = args.get_usize("trials", 3)?;
    let mut saturn = Saturn::new(c.clone());
    let overhead = saturn.profile(&w);
    let grid = saturn.grid.as_ref().unwrap();
    let spec = ExperimentSpec { trials, seed, ..Default::default() };
    let mut t = TextTable::new(vec!["policy", "makespan", "±ci90", "vs current practice"]);
    let mut cp_mean = 0.0;
    // run CurrentPractice first to anchor the comparison column
    let mut order = vec![PolicyKind::CurrentPractice];
    order.extend(PolicyKind::ALL.into_iter().filter(|k| *k != PolicyKind::CurrentPractice));
    for kind in order {
        let policy = policy_of(kind);
        let cfg = spec.sim_config(kind);
        let ms: Vec<f64> = (0..trials)
            .map(|k| {
                let mut rng = DetRng::new(seed + k as u64);
                simulate(policy.as_ref(), &w, grid, &c, cfg.clone(), &mut rng).makespan + overhead
            })
            .collect();
        let st = trial_stats(&ms);
        if kind == PolicyKind::CurrentPractice {
            cp_mean = st.mean;
        }
        let vs = if cp_mean > 0.0 && kind != PolicyKind::CurrentPractice {
            format!("{:.1}% lower", reduction_pct(st.mean, cp_mean))
        } else {
            "-".to_string()
        };
        t.row(vec![kind.tag().to_string(), saturn::util::fmt_hms(st.mean), format!("{:.0}s", st.ci90), vs]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_experiment(args: &Args) -> anyhow::Result<()> {
    if args.has("emit-default") {
        print!("{}", ExperimentSpec::default().to_json().pretty());
        return Ok(());
    }
    let spec = match args.flags.get("config") {
        Some(p) => ExperimentSpec::load(std::path::Path::new(p))?,
        None => ExperimentSpec::default(),
    };
    let w = match spec.workload {
        WorkloadKind::Txt => workloads::txt_workload(),
        WorkloadKind::Img => workloads::img_workload(),
    };
    let c = spec.build_cluster()?;
    let mut saturn = Saturn::new(c.clone());
    let overhead = saturn.profile(&w);
    let grid = saturn.grid.as_ref().unwrap();
    let mut t = TextTable::new(vec!["policy", "makespan(mean)", "±ci90"]);
    for &kind in &spec.policies {
        let policy = policy_of(kind);
        let cfg = spec.sim_config(kind);
        let ms: Vec<f64> = (0..spec.trials)
            .map(|k| {
                let mut rng = DetRng::new(spec.seed + k as u64);
                simulate(policy.as_ref(), &w, grid, &c, cfg.clone(), &mut rng).makespan + overhead
            })
            .collect();
        let st = trial_stats(&ms);
        t.row(vec![kind.tag().to_string(), saturn::util::fmt_hms(st.mean), format!("{:.0}s", st.ci90)]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_artifacts(args: &Args) -> anyhow::Result<()> {
    let dir = std::path::PathBuf::from(args.get("dir", "artifacts"));
    let manifest = saturn::runtime::Manifest::load(&dir)?;
    println!("manifest: {} artifacts", manifest.artifacts.len());
    let mut rt = saturn::runtime::Runtime::load(&dir)?;
    println!("PJRT platform: {}", rt.platform());
    for art in manifest.artifacts.clone() {
        rt.executable(&art.name)?;
        println!("  compiled {:<40} inputs={} outputs={}", art.name, art.inputs.len(), art.outputs.len());
    }
    println!("all artifacts compile OK");
    Ok(())
}
