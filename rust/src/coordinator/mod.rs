//! The user-facing Saturn facade — the paper's two-call API (Listing 3):
//! `profile([t1, t2, ...])` then `execute([t1, t2, ...])`.
//!
//! Wires the Parallelism Library, Trial Runner, Joint Optimizer, and the
//! execution backends (simulator for paper-scale clusters, real PJRT
//! executor for the e2e example) behind a single struct.
//!
//! This module is on the panic-sensitive path (see `LINTS.md`): the
//! facade fronts long-running online streams, so every fallible entry
//! point returns `anyhow::Result` instead of panicking, and the deny
//! attributes below keep clippy in agreement with `saturn-lint`.

#![deny(clippy::unwrap_used, clippy::expect_used)]

use crate::cluster::Cluster;
use crate::costmodel::CostModel;
use crate::parallelism::{Upp, UppRegistry};
use crate::profiler::{ProfileGrid, TrialRunner};
use crate::sched::Schedule;
use crate::sim::{simulate, SimConfig, SimResult};
use crate::solver::joint::JointOptimizer;
use crate::solver::policy::{PlanCtx, Policy};
use crate::trainer::Workload;
use crate::util::rng::DetRng;
use anyhow::{anyhow, Result};
use std::sync::Arc;

/// The Saturn system handle.
pub struct Saturn {
    /// Parallelism Library (UPP registry).
    pub registry: UppRegistry,
    /// The cluster Saturn schedules onto.
    pub cluster: Cluster,
    /// The joint optimizer.
    pub optimizer: JointOptimizer,
    /// Trial Runner output, populated by [`Saturn::profile`].
    pub grid: Option<ProfileGrid>,
    /// Simulated profiling overhead (seconds), populated with the grid.
    pub profile_overhead_secs: f64,
}

impl Saturn {
    /// New Saturn over a cluster with the default Parallelism Library
    /// (DDP, FSDP, GPipe, spilling).
    pub fn new(cluster: Cluster) -> Self {
        Self {
            registry: UppRegistry::default_library(Arc::new(CostModel::default())),
            cluster,
            optimizer: JointOptimizer::default(),
            grid: None,
            profile_overhead_secs: 0.0,
        }
    }

    /// Register a custom UPP (paper Listing 2).
    pub fn register(&mut self, name: &str, upp: Arc<dyn Upp>) {
        self.registry.register(name, upp);
    }

    /// Run the Trial Runner over the workload (paper: `profile(tasks)`).
    /// Returns the simulated profiling overhead in seconds.
    pub fn profile(&mut self, workload: &Workload) -> f64 {
        let runner = TrialRunner::new(self.registry.clone());
        let (grid, overhead) = runner.profile(workload, &self.cluster);
        self.grid = Some(grid);
        self.profile_overhead_secs = overhead;
        overhead
    }

    /// The profile grid, or a descriptive error if [`Saturn::profile`]
    /// has not run yet.
    fn grid(&self) -> Result<&ProfileGrid> {
        self.grid.as_ref().ok_or_else(|| anyhow!("no profile grid: call profile() first"))
    }

    /// Produce a one-shot execution plan (requires [`Saturn::profile`]).
    pub fn plan(&self, workload: &Workload, seed: u64) -> Result<Schedule> {
        let grid = self.grid()?;
        let ctx = PlanCtx::fresh(workload, grid, &self.cluster);
        let mut rng = DetRng::new(seed);
        Ok(self.optimizer.plan(&ctx, &mut rng))
    }

    /// Execute the workload in the simulator (paper: `execute(tasks)` on
    /// the simulated testbed). Introspection per `cfg`. Tasks with
    /// positive [`crate::trainer::Task::arrival`] times are injected at
    /// their arrival events, and [`SimConfig::chaos`] events (crashes,
    /// elastic joins/leaves, stragglers) cut running segments the same
    /// way — the result's robustness fields ([`SimResult::failures`],
    /// [`SimResult::relocations`], [`SimResult::lost_work_secs`],
    /// [`SimResult::time_to_recover`]) account for what each outage cost.
    pub fn execute_simulated(
        &self,
        workload: &Workload,
        cfg: SimConfig,
        seed: u64,
    ) -> Result<SimResult> {
        let grid = self.grid()?;
        let mut rng = DetRng::new(seed);
        Ok(simulate(&self.optimizer, workload, grid, &self.cluster, cfg, &mut rng))
    }

    /// Execute an online workload (tasks arriving over time) and return
    /// queueing statistics alongside the raw result. Uses the incremental
    /// re-solve mode of the joint optimizer for arrival events.
    pub fn execute_online(
        &self,
        workload: &Workload,
        cfg: SimConfig,
        seed: u64,
    ) -> Result<(SimResult, crate::metrics::OnlineStats)> {
        let grid = self.grid()?;
        let optimizer = JointOptimizer { incremental: true, ..self.optimizer.clone() };
        let mut rng = DetRng::new(seed);
        let result = simulate(&optimizer, workload, grid, &self.cluster, cfg, &mut rng);
        let stats = crate::metrics::online_stats(workload, &result);
        Ok((result, stats))
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::trainer::workloads;

    #[test]
    fn profile_then_plan_then_execute() {
        let mut saturn = Saturn::new(Cluster::single_node_8gpu());
        let w = workloads::txt_workload();
        let overhead = saturn.profile(&w);
        assert!(overhead > 0.0);
        let plan = saturn.plan(&w, 1).unwrap();
        plan.validate(&saturn.cluster, &w).unwrap();
        let result = saturn.execute_simulated(&w, SimConfig::default(), 1).unwrap();
        assert_eq!(result.completions.len(), w.len());
    }

    /// The facade executes chaos streams end to end: a crash/repair pair
    /// runs deterministically, every task completes on the repaired
    /// capacity, and the robustness accounting reaches the caller.
    #[test]
    fn execute_simulated_with_chaos_events() {
        use crate::cluster::{ClusterEvent, TimedClusterEvent};
        let mut saturn = Saturn::new(Cluster::single_node_8gpu());
        saturn.optimizer.timeout = std::time::Duration::from_secs(240);
        let w = workloads::txt_workload();
        saturn.profile(&w);
        let cfg = SimConfig {
            chaos: vec![
                TimedClusterEvent { at: 100.0, event: ClusterEvent::NodeFail { node: 0 } },
                TimedClusterEvent { at: 200.0, event: ClusterEvent::NodeJoin { node: 0 } },
            ],
            ..SimConfig::default()
        };
        let a = saturn.execute_simulated(&w, cfg.clone(), 5).unwrap();
        let b = saturn.execute_simulated(&w, cfg, 5).unwrap();
        assert_eq!(a, b, "chaos execution must be deterministic");
        assert_eq!(a.completions.len(), w.len());
        assert_eq!(a.failures, 1);
        assert_eq!(a.capacity_trace.first(), Some(&(0.0, 8)));
        assert!(a.capacity_trace.contains(&(100.0, 0)));
        assert!(a.makespan > 200.0, "the stream can only finish after the repair");
    }

    #[test]
    fn plan_without_profile_is_an_error_not_a_panic() {
        let saturn = Saturn::new(Cluster::single_node_8gpu());
        let w = workloads::txt_workload();
        let err = saturn.plan(&w, 1).unwrap_err();
        assert!(err.to_string().contains("profile()"), "{err}");
        assert!(saturn.execute_simulated(&w, SimConfig::default(), 1).is_err());
        assert!(saturn.execute_online(&w, SimConfig::default(), 1).is_err());
    }
}
