//! The user-facing Saturn facade — the paper's two-call API (Listing 3):
//! `profile([t1, t2, ...])` then `execute([t1, t2, ...])`.
//!
//! Wires the Parallelism Library, Trial Runner, Joint Optimizer, and the
//! execution backends (simulator for paper-scale clusters, real PJRT
//! executor for the e2e example) behind a single struct.
//!
//! This module is on the panic-sensitive path (see `LINTS.md`): the
//! facade fronts long-running online streams, so every fallible entry
//! point returns `anyhow::Result` instead of panicking, and the deny
//! attributes below keep clippy in agreement with `saturn-lint`.

#![deny(clippy::unwrap_used, clippy::expect_used)]

use crate::cluster::{Cluster, NodeReliability};
use crate::costmodel::CostModel;
use crate::parallelism::{Upp, UppRegistry};
use crate::profiler::{ProfileGrid, TrialRunner};
use crate::sched::Schedule;
use crate::sim::{simulate, SimConfig, SimResult};
use crate::solver::joint::JointOptimizer;
use crate::solver::policy::{PlanCtx, Policy};
use crate::trainer::Workload;
use crate::util::rng::DetRng;
use anyhow::{anyhow, Result};
use std::sync::Arc;

/// The Saturn system handle.
pub struct Saturn {
    /// Parallelism Library (UPP registry).
    pub registry: UppRegistry,
    /// The cluster Saturn schedules onto.
    pub cluster: Cluster,
    /// The joint optimizer.
    pub optimizer: JointOptimizer,
    /// Trial Runner output, populated by [`Saturn::profile`].
    pub grid: Option<ProfileGrid>,
    /// Simulated profiling overhead (seconds), populated with the grid.
    pub profile_overhead_secs: f64,
    /// Per-node reliability model for failure-aware planning. Empty (the
    /// default) keeps every plan risk-blind; install one with
    /// [`Saturn::set_reliability`]. [`Saturn::plan`] prices expected lost
    /// work + restarts into every placement, and the execute paths adopt
    /// it as the default whenever the passed [`SimConfig`] carries no
    /// model of its own.
    pub reliability: Vec<Option<NodeReliability>>,
    /// Checkpoint write cost, seconds — the `C` in the Young/Daly
    /// interval √(2·C·MTBF). Travels with [`Saturn::reliability`].
    pub ckpt_cost: f64,
}

impl Saturn {
    /// New Saturn over a cluster with the default Parallelism Library
    /// (DDP, FSDP, GPipe, spilling).
    pub fn new(cluster: Cluster) -> Self {
        Self {
            registry: UppRegistry::default_library(Arc::new(CostModel::default())),
            cluster,
            optimizer: JointOptimizer::default(),
            grid: None,
            profile_overhead_secs: 0.0,
            reliability: Vec::new(),
            ckpt_cost: 0.0,
        }
    }

    /// Install a per-node reliability model (and the checkpoint write
    /// cost it prices) after validating it at the API edge: one entry
    /// per node, positive MTBF (∞ = never fails), finite non-negative
    /// restart delay, finite non-negative checkpoint cost. `None`
    /// entries keep that node risk-blind.
    pub fn set_reliability(
        &mut self,
        reliability: Vec<Option<NodeReliability>>,
        ckpt_cost: f64,
    ) -> Result<()> {
        anyhow::ensure!(
            reliability.len() == self.cluster.nodes.len(),
            "reliability has {} entries but the cluster has {} nodes",
            reliability.len(),
            self.cluster.nodes.len()
        );
        for (node, rel) in reliability.iter().enumerate() {
            if let Some(r) = rel {
                anyhow::ensure!(
                    !r.mtbf_secs.is_nan() && r.mtbf_secs > 0.0,
                    "node {node}: MTBF must be positive (∞ = never fails), got {}",
                    r.mtbf_secs
                );
                anyhow::ensure!(
                    r.restart_secs.is_finite() && r.restart_secs >= 0.0,
                    "node {node}: restart delay must be finite and non-negative, got {}",
                    r.restart_secs
                );
            }
        }
        anyhow::ensure!(
            ckpt_cost.is_finite() && ckpt_cost >= 0.0,
            "checkpoint cost must be finite and non-negative, got {ckpt_cost}"
        );
        self.reliability = reliability;
        self.ckpt_cost = ckpt_cost;
        Ok(())
    }

    /// Register a custom UPP (paper Listing 2).
    pub fn register(&mut self, name: &str, upp: Arc<dyn Upp>) {
        self.registry.register(name, upp);
    }

    /// Run the Trial Runner over the workload (paper: `profile(tasks)`).
    /// Returns the simulated profiling overhead in seconds.
    pub fn profile(&mut self, workload: &Workload) -> f64 {
        let runner = TrialRunner::new(self.registry.clone());
        let (grid, overhead) = runner.profile(workload, &self.cluster);
        self.grid = Some(grid);
        self.profile_overhead_secs = overhead;
        overhead
    }

    /// The profile grid, or a descriptive error if [`Saturn::profile`]
    /// has not run yet.
    fn grid(&self) -> Result<&ProfileGrid> {
        self.grid.as_ref().ok_or_else(|| anyhow!("no profile grid: call profile() first"))
    }

    /// Produce a one-shot execution plan (requires [`Saturn::profile`]).
    /// With a model from [`Saturn::set_reliability`] installed, every
    /// placement is scored with its expected lost work + restarts.
    pub fn plan(&self, workload: &Workload, seed: u64) -> Result<Schedule> {
        let grid = self.grid()?;
        let mut ctx = PlanCtx::fresh(workload, grid, &self.cluster);
        ctx.reliability = self.reliability.clone();
        ctx.ckpt_cost = self.ckpt_cost;
        let mut rng = DetRng::new(seed);
        Ok(self.optimizer.plan(&ctx, &mut rng))
    }

    /// The simulation config with the facade's reliability model adopted
    /// as the default when `cfg` carries none of its own.
    fn with_reliability_default(&self, mut cfg: SimConfig) -> SimConfig {
        if cfg.reliability.is_empty() && !self.reliability.is_empty() {
            cfg.reliability = self.reliability.clone();
            cfg.ckpt_cost = self.ckpt_cost;
        }
        cfg
    }

    /// Execute the workload in the simulator (paper: `execute(tasks)` on
    /// the simulated testbed). Introspection per `cfg`. Tasks with
    /// positive [`crate::trainer::Task::arrival`] times are injected at
    /// their arrival events, and [`SimConfig::chaos`] events (crashes,
    /// elastic joins/leaves, stragglers) cut running segments the same
    /// way — the result's robustness fields ([`SimResult::failures`],
    /// [`SimResult::relocations`], [`SimResult::lost_work_secs`],
    /// [`SimResult::time_to_recover`]) account for what each outage cost.
    pub fn execute_simulated(
        &self,
        workload: &Workload,
        cfg: SimConfig,
        seed: u64,
    ) -> Result<SimResult> {
        let grid = self.grid()?;
        let cfg = self.with_reliability_default(cfg);
        let mut rng = DetRng::new(seed);
        Ok(simulate(&self.optimizer, workload, grid, &self.cluster, cfg, &mut rng))
    }

    /// Execute an online workload (tasks arriving over time) and return
    /// queueing statistics alongside the raw result. Uses the incremental
    /// re-solve mode of the joint optimizer for arrival events.
    pub fn execute_online(
        &self,
        workload: &Workload,
        cfg: SimConfig,
        seed: u64,
    ) -> Result<(SimResult, crate::metrics::OnlineStats)> {
        let grid = self.grid()?;
        let cfg = self.with_reliability_default(cfg);
        let optimizer = JointOptimizer { incremental: true, ..self.optimizer.clone() };
        let mut rng = DetRng::new(seed);
        let result = simulate(&optimizer, workload, grid, &self.cluster, cfg, &mut rng);
        let stats = crate::metrics::online_stats(workload, &result);
        Ok((result, stats))
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::trainer::workloads;

    #[test]
    fn profile_then_plan_then_execute() {
        let mut saturn = Saturn::new(Cluster::single_node_8gpu());
        let w = workloads::txt_workload();
        let overhead = saturn.profile(&w);
        assert!(overhead > 0.0);
        let plan = saturn.plan(&w, 1).unwrap();
        plan.validate(&saturn.cluster, &w).unwrap();
        let result = saturn.execute_simulated(&w, SimConfig::default(), 1).unwrap();
        assert_eq!(result.completions.len(), w.len());
    }

    /// The facade executes chaos streams end to end: a crash/repair pair
    /// runs deterministically, every task completes on the repaired
    /// capacity, and the robustness accounting reaches the caller.
    #[test]
    fn execute_simulated_with_chaos_events() {
        use crate::cluster::{ClusterEvent, TimedClusterEvent};
        let mut saturn = Saturn::new(Cluster::single_node_8gpu());
        saturn.optimizer.timeout = std::time::Duration::from_secs(240);
        let w = workloads::txt_workload();
        saturn.profile(&w);
        let cfg = SimConfig {
            chaos: vec![
                TimedClusterEvent { at: 100.0, event: ClusterEvent::NodeFail { node: 0 } },
                TimedClusterEvent { at: 200.0, event: ClusterEvent::NodeJoin { node: 0 } },
            ],
            ..SimConfig::default()
        };
        let a = saturn.execute_simulated(&w, cfg.clone(), 5).unwrap();
        let b = saturn.execute_simulated(&w, cfg, 5).unwrap();
        assert_eq!(a, b, "chaos execution must be deterministic");
        assert_eq!(a.completions.len(), w.len());
        assert_eq!(a.failures, 1);
        assert_eq!(a.capacity_trace.first(), Some(&(0.0, 8)));
        assert!(a.capacity_trace.contains(&(100.0, 0)));
        assert!(a.makespan > 200.0, "the stream can only finish after the repair");
    }

    /// The reliability model is surfaced through the facade with edge
    /// validation, and a "never fails" model (MTBF ∞, zero restart)
    /// contributes zero expected loss — the risk-enabled evaluator path
    /// produces a plan byte-identical to the risk-blind one.
    #[test]
    fn reliability_surfaced_and_reliable_nodes_change_nothing() {
        let mut saturn = Saturn::new(Cluster::single_node_8gpu());
        saturn.optimizer.timeout = std::time::Duration::from_secs(240);
        // junk models are rejected at the edge, state untouched
        assert!(saturn.set_reliability(vec![None, None], 0.0).is_err());
        assert!(saturn
            .set_reliability(vec![Some(NodeReliability::new(f64::NAN, 0.0))], 0.0)
            .is_err());
        assert!(saturn
            .set_reliability(vec![Some(NodeReliability::new(800.0, -1.0))], 0.0)
            .is_err());
        assert!(saturn
            .set_reliability(vec![Some(NodeReliability::new(800.0, 200.0))], f64::NAN)
            .is_err());
        assert!(saturn.reliability.is_empty());
        let w = workloads::txt_workload();
        saturn.profile(&w);
        let blind = saturn.plan(&w, 3).unwrap();
        saturn.set_reliability(vec![Some(NodeReliability::reliable())], 25.0).unwrap();
        let riskful = saturn.plan(&w, 3).unwrap();
        assert_eq!(blind, riskful, "zero expected loss must not perturb the plan");
        riskful.validate(&saturn.cluster, &w).unwrap();
    }

    #[test]
    fn plan_without_profile_is_an_error_not_a_panic() {
        let saturn = Saturn::new(Cluster::single_node_8gpu());
        let w = workloads::txt_workload();
        let err = saturn.plan(&w, 1).unwrap_err();
        assert!(err.to_string().contains("profile()"), "{err}");
        assert!(saturn.execute_simulated(&w, SimConfig::default(), 1).is_err());
        assert!(saturn.execute_online(&w, SimConfig::default(), 1).is_err());
    }
}
