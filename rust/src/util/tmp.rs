//! Scoped temporary directories (the `tempfile` crate is unavailable
//! offline). Used by tests and by report-writing helpers.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A temp directory removed on drop.
#[derive(Debug)]
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    /// Create a fresh unique directory under the system temp dir.
    pub fn new(prefix: &str) -> std::io::Result<Self> {
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let pid = std::process::id();
        let t = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos())
            .unwrap_or(0);
        let path = std::env::temp_dir().join(format!("saturn-{prefix}-{pid}-{t}-{n}"));
        std::fs::create_dir_all(&path)?;
        Ok(Self { path })
    }

    /// The directory path.
    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn creates_and_cleans_up() {
        let p;
        {
            let d = TempDir::new("test").unwrap();
            p = d.path().to_path_buf();
            assert!(p.exists());
            std::fs::write(p.join("f.txt"), "x").unwrap();
        }
        assert!(!p.exists());
    }

    #[test]
    fn unique_paths() {
        let a = TempDir::new("u").unwrap();
        let b = TempDir::new("u").unwrap();
        assert_ne!(a.path(), b.path());
    }

    /// Regression: naming keyed on `SystemTime::now` alone collides when
    /// two dirs are created inside one clock tick. The PID + atomic
    /// counter must keep paths distinct even when many threads allocate
    /// simultaneously with the same prefix.
    #[test]
    fn concurrent_paths_are_distinct() {
        const THREADS: usize = 8;
        const PER_THREAD: usize = 32;
        let handles: Vec<_> = (0..THREADS)
            .map(|_| {
                std::thread::spawn(|| {
                    let dirs: Vec<TempDir> =
                        (0..PER_THREAD).map(|_| TempDir::new("race").unwrap()).collect();
                    dirs.iter().map(|d| d.path().to_path_buf()).collect::<Vec<_>>()
                })
            })
            .collect();
        let mut all: Vec<std::path::PathBuf> = Vec::new();
        for h in handles {
            all.extend(h.join().unwrap());
        }
        assert_eq!(all.len(), THREADS * PER_THREAD);
        let mut dedup = all.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), all.len(), "temp paths collided under concurrency");
    }
}
