//! Scoped temporary directories (the `tempfile` crate is unavailable
//! offline). Used by tests and by report-writing helpers.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A temp directory removed on drop.
#[derive(Debug)]
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    /// Create a fresh unique directory under the system temp dir.
    pub fn new(prefix: &str) -> std::io::Result<Self> {
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let pid = std::process::id();
        let t = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos())
            .unwrap_or(0);
        let path = std::env::temp_dir().join(format!("saturn-{prefix}-{pid}-{t}-{n}"));
        std::fs::create_dir_all(&path)?;
        Ok(Self { path })
    }

    /// The directory path.
    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn creates_and_cleans_up() {
        let p;
        {
            let d = TempDir::new("test").unwrap();
            p = d.path().to_path_buf();
            assert!(p.exists());
            std::fs::write(p.join("f.txt"), "x").unwrap();
        }
        assert!(!p.exists());
    }

    #[test]
    fn unique_paths() {
        let a = TempDir::new("u").unwrap();
        let b = TempDir::new("u").unwrap();
        assert_ne!(a.path(), b.path());
    }
}
