//! Minimal JSON: a value type, a recursive-descent parser, and a writer.
//!
//! The offline build environment vendors no JSON crate, so Saturn carries
//! its own. It covers everything the system needs — the artifact manifest
//! written by `python/compile/aot.py`, experiment specs, and report
//! output — with strict UTF-8 strings, `\uXXXX` escapes, and f64 numbers.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any number (f64).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (sorted keys for deterministic output).
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    /// What went wrong.
    pub msg: String,
    /// Byte offset in the input.
    pub at: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// As string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As f64.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// As usize (rejects negatives / non-integers).
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    /// As u64.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// As array slice.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Build an object from (key, value) pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Serialize compactly.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Serialize with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                if !a.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        out.push_str(&" ".repeat(w * depth));
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), at: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let start = self.i;
            // fast path: run of plain bytes
            while self.i < self.b.len() && self.b[self.i] != b'"' && self.b[self.i] != b'\\' {
                self.i += 1;
            }
            s.push_str(
                std::str::from_utf8(&self.b[start..self.i]).map_err(|_| self.err("invalid UTF-8"))?,
            );
            match self.peek() {
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .ok_or_else(|| self.err("bad \\u escape"))?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u hex"))?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                _ => return Err(self.err("unterminated string")),
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self.peek().is_some_and(|c| c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let txt =
            std::str::from_utf8(&self.b[start..self.i]).map_err(|_| self.err("bad number"))?;
        txt.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" A");
    }

    #[test]
    fn roundtrip() {
        let v = Json::obj(vec![
            ("name", Json::Str("tiny \"lm\"".into())),
            ("n", Json::Num(42.0)),
            ("pi", Json::Num(3.25)),
            ("flags", Json::Arr(vec![Json::Bool(true), Json::Null])),
        ]);
        for s in [v.dump(), v.pretty()] {
            assert_eq!(Json::parse(&s).unwrap(), v, "{s}");
        }
    }

    #[test]
    fn integers_emit_without_fraction() {
        assert_eq!(Json::Num(5.0).dump(), "5");
        assert_eq!(Json::Num(5.5).dump(), "5.5");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn accessor_types() {
        let v = Json::parse(r#"{"u": 7, "f": 1.5, "neg": -2}"#).unwrap();
        assert_eq!(v.get("u").unwrap().as_usize(), Some(7));
        assert_eq!(v.get("f").unwrap().as_usize(), None);
        assert_eq!(v.get("neg").unwrap().as_usize(), None);
        assert_eq!(v.get("f").unwrap().as_f64(), Some(1.5));
    }

    #[test]
    fn whitespace_tolerant() {
        let v = Json::parse(" {\n\t\"a\" :\r [ ] } ").unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 0);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
        assert_eq!(Json::Arr(vec![]).dump(), "[]");
    }
}
