//! Small shared utilities: deterministic RNG, wall-clock deadlines, and
//! formatting helpers used across Saturn's modules.

pub mod bench;
pub mod json;
pub mod rng;
pub mod table;
pub mod tmp;

use std::time::{Duration, Instant};

/// A wall-clock deadline used by anytime solvers (the paper runs Gurobi
/// under a fixed timeout and takes the incumbent).
#[derive(Debug, Clone, Copy)]
pub struct Deadline {
    start: Instant,
    budget: Duration,
}

impl Deadline {
    /// Create a deadline `budget` from now.
    pub fn after(budget: Duration) -> Self {
        // lint:allow(clock-in-evaluator) -- Deadline IS the sanctioned clock facade: the one Instant::now on the plan path, captured once at construction; workers only poll expired() at batch boundaries
        Self { start: Instant::now(), budget }
    }

    /// Convenience constructor from seconds.
    pub fn after_secs(secs: f64) -> Self {
        Self::after(Duration::from_secs_f64(secs))
    }

    /// True once the budget is exhausted.
    pub fn expired(&self) -> bool {
        self.start.elapsed() >= self.budget
    }

    /// Time remaining (zero if expired).
    pub fn remaining(&self) -> Duration {
        self.budget.saturating_sub(self.start.elapsed())
    }

    /// Fraction of the budget consumed, clamped to [0, 1]. A zero-duration
    /// budget reports 1.0 (already expired), not the NaN of 0.0/0.0 — the
    /// introspection loop reads this for pacing and NaN poisons every
    /// comparison downstream.
    pub fn progress(&self) -> f64 {
        if self.budget.is_zero() {
            return 1.0;
        }
        (self.start.elapsed().as_secs_f64() / self.budget.as_secs_f64()).min(1.0)
    }
}

/// How many iterations hot solver loops run between wall-clock reads.
/// `Instant::now` per candidate was noise while candidate evaluation cost
/// O(n·m); once the delta kernel made moves cheap it became a measurable
/// fixed tax, so the annealers poll through [`DeadlinePoll`] instead.
pub const DEADLINE_POLL_PERIOD: u32 = 64;

/// Amortized deadline polling for hot loops: reads the clock on the first
/// call and then only every `period`-th call, so an anytime search pays
/// one `Instant::now` per batch of candidate evaluations. Worst-case
/// budget overshoot is `period - 1` iterations.
#[derive(Debug, Clone)]
pub struct DeadlinePoll {
    deadline: Deadline,
    period: u32,
    count: u32,
}

impl DeadlinePoll {
    /// Poll `deadline` every `period` calls (first call always polls).
    pub fn new(deadline: Deadline, period: u32) -> Self {
        assert!(period > 0, "poll period must be positive");
        Self { deadline, period, count: period - 1 }
    }

    /// True once the underlying deadline has expired, checked on the
    /// first and then every `period`-th call.
    pub fn expired(&mut self) -> bool {
        self.expired_batch(1)
    }

    /// Batch variant for speculative solvers: advance the iteration count
    /// by `n` (one call covers a whole batch of candidate evaluations)
    /// and poll the clock whenever a period boundary is crossed. The
    /// residual count carries across the boundary, so batches cross
    /// boundaries exactly as `n` single calls would and the worst-case
    /// overshoot bound stays `period - 1` iterations (plus the batch in
    /// flight). A coordinator scoring batches of K keeps the same ~1
    /// clock read per `period` evaluations as the sequential loop;
    /// workers never touch the clock at all — mid-batch aborts would make
    /// the trajectory depend on wall-clock timing, so batches always run
    /// to completion and only batch *boundaries* are deadline-checked.
    pub fn expired_batch(&mut self, n: u32) -> bool {
        self.count = self.count.saturating_add(n);
        if self.count >= self.period {
            self.count %= self.period;
            return self.deadline.expired();
        }
        false
    }
}

/// Round `x` to `d` decimal places (report formatting).
pub fn round_to(x: f64, d: u32) -> f64 {
    let p = 10f64.powi(d as i32);
    (x * p).round() / p
}

/// Format a duration in seconds as `h:mm:ss`.
pub fn fmt_hms(secs: f64) -> String {
    let s = secs.max(0.0).round() as u64;
    format!("{}:{:02}:{:02}", s / 3600, (s % 3600) / 60, s % 60)
}

/// Approximate float equality with relative + absolute tolerance.
pub fn approx_eq(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deadline_expires() {
        let d = Deadline::after(Duration::from_millis(1));
        std::thread::sleep(Duration::from_millis(5));
        assert!(d.expired());
        assert_eq!(d.remaining(), Duration::ZERO);
        assert!((d.progress() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn deadline_not_expired() {
        let d = Deadline::after(Duration::from_secs(60));
        assert!(!d.expired());
        assert!(d.remaining() > Duration::from_secs(59));
        assert!(d.progress() < 0.1);
    }

    #[test]
    fn zero_budget_progress_is_one() {
        // 0.0 / 0.0 used to surface as NaN, poisoning pacing comparisons
        let d = Deadline::after(Duration::ZERO);
        assert_eq!(d.progress(), 1.0);
        assert!(d.expired());
        assert_eq!(d.remaining(), Duration::ZERO);
    }

    #[test]
    fn deadline_poll_amortizes_clock_reads() {
        // expired deadline: noticed on the very first call
        let mut p = DeadlinePoll::new(Deadline::after(Duration::ZERO), 8);
        assert!(p.expired());
        // live deadline: the off-cycle calls never read the clock and the
        // on-cycle ones report not-expired
        let mut q = DeadlinePoll::new(Deadline::after(Duration::from_secs(60)), 8);
        for _ in 0..64 {
            assert!(!q.expired());
        }
        // once the underlying deadline passes, a poll within one period sees it
        let mut r = DeadlinePoll::new(Deadline::after(Duration::from_millis(1)), 4);
        std::thread::sleep(Duration::from_millis(5));
        let fired = (0..4).any(|_| r.expired());
        assert!(fired, "poll must fire within one period of expiry");
    }

    #[test]
    fn deadline_poll_batches_count_like_singles() {
        // advancing by n must cross period boundaries exactly like n
        // single calls would: 8-period poll, batches of 3 → the clock is
        // read on calls 1, 3 (count 9 ≥ 8) and then every ~3rd call
        let mut p = DeadlinePoll::new(Deadline::after(Duration::from_secs(60)), 8);
        for _ in 0..100 {
            assert!(!p.expired_batch(3));
        }
        // an expired deadline is noticed on the first batch regardless of
        // batch size (the constructor pre-loads the counter)
        let mut q = DeadlinePoll::new(Deadline::after(Duration::ZERO), 64);
        assert!(q.expired_batch(5));
        // and within one period's worth of iterations afterwards
        let mut r = DeadlinePoll::new(Deadline::after(Duration::from_millis(1)), 16);
        std::thread::sleep(Duration::from_millis(5));
        let fired = (0..4).any(|_| r.expired_batch(7));
        assert!(fired, "batch poll must fire within one period of expiry");
    }

    #[test]
    fn round_to_places() {
        assert_eq!(round_to(1.23456, 2), 1.23);
        assert_eq!(round_to(1.235, 2), 1.24);
        assert_eq!(round_to(-1.235, 0), -1.0);
    }

    #[test]
    fn fmt_hms_basic() {
        assert_eq!(fmt_hms(0.0), "0:00:00");
        assert_eq!(fmt_hms(3661.0), "1:01:01");
        assert_eq!(fmt_hms(-5.0), "0:00:00");
    }

    #[test]
    fn approx_eq_tolerance() {
        assert!(approx_eq(1.0, 1.0 + 1e-12, 1e-9));
        assert!(!approx_eq(1.0, 1.1, 1e-3));
        assert!(approx_eq(1e9, 1e9 + 1.0, 1e-6));
    }
}
