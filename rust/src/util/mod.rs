//! Small shared utilities: deterministic RNG, wall-clock deadlines, and
//! formatting helpers used across Saturn's modules.

pub mod bench;
pub mod json;
pub mod rng;
pub mod table;
pub mod tmp;

use std::time::{Duration, Instant};

/// A wall-clock deadline used by anytime solvers (the paper runs Gurobi
/// under a fixed timeout and takes the incumbent).
#[derive(Debug, Clone, Copy)]
pub struct Deadline {
    start: Instant,
    budget: Duration,
}

impl Deadline {
    /// Create a deadline `budget` from now.
    pub fn after(budget: Duration) -> Self {
        Self { start: Instant::now(), budget }
    }

    /// Convenience constructor from seconds.
    pub fn after_secs(secs: f64) -> Self {
        Self::after(Duration::from_secs_f64(secs))
    }

    /// True once the budget is exhausted.
    pub fn expired(&self) -> bool {
        self.start.elapsed() >= self.budget
    }

    /// Time remaining (zero if expired).
    pub fn remaining(&self) -> Duration {
        self.budget.saturating_sub(self.start.elapsed())
    }

    /// Fraction of the budget consumed, clamped to [0, 1].
    pub fn progress(&self) -> f64 {
        (self.start.elapsed().as_secs_f64() / self.budget.as_secs_f64()).min(1.0)
    }
}

/// Round `x` to `d` decimal places (report formatting).
pub fn round_to(x: f64, d: u32) -> f64 {
    let p = 10f64.powi(d as i32);
    (x * p).round() / p
}

/// Format a duration in seconds as `h:mm:ss`.
pub fn fmt_hms(secs: f64) -> String {
    let s = secs.max(0.0).round() as u64;
    format!("{}:{:02}:{:02}", s / 3600, (s % 3600) / 60, s % 60)
}

/// Approximate float equality with relative + absolute tolerance.
pub fn approx_eq(a: f64, b: f64, tol: f64) -> bool {
    (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deadline_expires() {
        let d = Deadline::after(Duration::from_millis(1));
        std::thread::sleep(Duration::from_millis(5));
        assert!(d.expired());
        assert_eq!(d.remaining(), Duration::ZERO);
        assert!((d.progress() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn deadline_not_expired() {
        let d = Deadline::after(Duration::from_secs(60));
        assert!(!d.expired());
        assert!(d.remaining() > Duration::from_secs(59));
        assert!(d.progress() < 0.1);
    }

    #[test]
    fn round_to_places() {
        assert_eq!(round_to(1.23456, 2), 1.23);
        assert_eq!(round_to(1.235, 2), 1.24);
        assert_eq!(round_to(-1.235, 0), -1.0);
    }

    #[test]
    fn fmt_hms_basic() {
        assert_eq!(fmt_hms(0.0), "0:00:00");
        assert_eq!(fmt_hms(3661.0), "1:01:01");
        assert_eq!(fmt_hms(-5.0), "0:00:00");
    }

    #[test]
    fn approx_eq_tolerance() {
        assert!(approx_eq(1.0, 1.0 + 1e-12, 1e-9));
        assert!(!approx_eq(1.0, 1.1, 1e-3));
        assert!(approx_eq(1e9, 1e9 + 1.0, 1e-6));
    }
}
