//! Minimal plain-text table renderer for experiment binaries.
//!
//! The paper's evaluation is tables and figures; our experiment examples
//! print the same rows/series as aligned ASCII tables so the "shape" of each
//! result is inspectable in a terminal and diffable in EXPERIMENTS.md.

/// Column-aligned text table.
#[derive(Debug, Default, Clone)]
pub struct TextTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TextTable {
    /// New table with the given header cells.
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Self { header: header.into_iter().map(Into::into).collect(), rows: Vec::new() }
    }

    /// Append a row (padded/truncated to header width).
    pub fn row<S: Into<String>>(&mut self, cells: Vec<S>) -> &mut Self {
        let mut r: Vec<String> = cells.into_iter().map(Into::into).collect();
        r.resize(self.header.len(), String::new());
        self.rows.push(r);
        self
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True if no data rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Render to a String with column alignment and a separator line.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate().take(ncol) {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
                .trim_end()
                .to_string()
        };
        let mut out = String::new();
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }

    /// Render as CSV (for plotting scripts).
    pub fn to_csv(&self) -> String {
        let esc = |s: &String| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.clone()
            }
        };
        let mut out = self.header.iter().map(esc).collect::<Vec<_>>().join(",");
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.iter().map(esc).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = TextTable::new(vec!["name", "value"]);
        t.row(vec!["short", "1"]);
        t.row(vec!["a-much-longer-name", "22.5"]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].starts_with("name"));
        assert!(lines[3].starts_with("a-much-longer-name"));
        // aligned: "value"/"1"/"22.5" start at the same column
        let col = lines[0].find("value").unwrap();
        assert_eq!(&lines[2][col..col + 1], "1");
    }

    #[test]
    fn pads_missing_cells() {
        let mut t = TextTable::new(vec!["a", "b", "c"]);
        t.row(vec!["x"]);
        assert_eq!(t.len(), 1);
        assert!(t.render().contains('x'));
    }

    #[test]
    fn csv_escaping() {
        let mut t = TextTable::new(vec!["k", "v"]);
        t.row(vec!["has,comma", "has\"quote"]);
        let csv = t.to_csv();
        assert!(csv.contains("\"has,comma\""));
        assert!(csv.contains("\"has\"\"quote\""));
    }
}
