//! Deterministic, seedable RNG — self-contained (no external crates).
//!
//! All stochastic components in Saturn (randomized baseline, simulator
//! noise, annealing moves, synthetic data) draw from [`DetRng`] so
//! experiments are reproducible run-to-run — every experiment binary takes
//! an explicit seed. The generator is xoshiro256**, seeded via splitmix64.

/// Deterministic RNG (xoshiro256**).
#[derive(Debug, Clone)]
pub struct DetRng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl DetRng {
    /// Create from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Self { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Next raw 64-bit value.
    pub fn u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Derive an independent child stream (stable, seed-mixed).
    pub fn fork(&mut self, stream: u64) -> Self {
        let s = self.u64() ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Self::new(s)
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits
        (self.u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Panics if n == 0.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "DetRng::below(0)");
        // Lemire multiply-shift with rejection for unbiased sampling
        let n = n as u64;
        let threshold = n.wrapping_neg() % n; // 2^64 mod n
        loop {
            let m = (self.u64() as u128) * (n as u128);
            if (m as u64) >= threshold {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Pick a random element of a non-empty slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Fisher–Yates shuffle in place.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Standard normal sample (Box–Muller).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Multiplicative log-normal noise factor with given sigma, mean ~1.
    /// Used by the simulator to perturb profiled estimates into "actual"
    /// runtimes (real minibatch times jitter around the profiled mean).
    pub fn noise_factor(&mut self, sigma: f64) -> f64 {
        (self.normal() * sigma - 0.5 * sigma * sigma).exp()
    }

    /// One Metropolis acceptance test at temperature `temp`: a strictly
    /// improving candidate (`cand < cur`) is accepted **without consuming
    /// randomness**; anything else draws exactly one uniform and accepts
    /// with probability `exp((cur − cand) / temp)`.
    ///
    /// The conditional draw is part of the annealing determinism
    /// contract: the speculative batch engine and the sequential loop
    /// (`solver::anneal`) must consume the stream identically move for
    /// move, so the acceptance rule lives here in one place instead of
    /// being copy-pasted per loop.
    pub fn metropolis(&mut self, cur: f64, cand: f64, temp: f64) -> bool {
        cand < cur || self.f64() < ((cur - cand) / temp).exp()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = DetRng::new(7);
        let mut b = DetRng::new(7);
        for _ in 0..100 {
            assert_eq!(a.u64(), b.u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = DetRng::new(1);
        let mut b = DetRng::new(2);
        let same = (0..32).filter(|_| a.u64() == b.u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = DetRng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = DetRng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all values hit: {seen:?}");
    }

    #[test]
    fn below_unbiased_small_n() {
        let mut r = DetRng::new(5);
        let n = 3;
        let mut counts = [0usize; 3];
        let trials = 60_000;
        for _ in 0..trials {
            counts[r.below(n)] += 1;
        }
        for &c in &counts {
            let p = c as f64 / trials as f64;
            assert!((p - 1.0 / 3.0).abs() < 0.01, "{counts:?}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = DetRng::new(5);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = DetRng::new(9);
        let mut c1 = root.fork(1);
        let mut c2 = root.fork(2);
        let same = (0..32).filter(|_| c1.u64() == c2.u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn metropolis_draw_discipline() {
        // improving candidates consume nothing: the stream stays aligned
        let mut a = DetRng::new(31);
        let mut b = DetRng::new(31);
        assert!(a.metropolis(100.0, 50.0, 10.0));
        assert_eq!(a.u64(), b.u64(), "improving accept must not draw");
        // non-improving candidates consume exactly one uniform
        let mut c = DetRng::new(32);
        let mut d = DetRng::new(32);
        c.metropolis(100.0, 120.0, 10.0);
        let _ = d.f64();
        assert_eq!(c.u64(), d.u64(), "worse candidate must draw exactly once");
        // equal makespans accept with probability 1 (plateau exploration)
        let mut e = DetRng::new(33);
        assert!(e.metropolis(100.0, 100.0, 1e-9));
        // a hopeless candidate at tiny temperature is (almost surely)
        // rejected: exp of a hugely negative number underflows to 0
        let mut f = DetRng::new(34);
        assert!(!f.metropolis(100.0, 1e9, 1e-9));
    }

    #[test]
    fn noise_factor_near_one() {
        let mut r = DetRng::new(11);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.noise_factor(0.1)).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = DetRng::new(13);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }
}
