//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! `harness = false` bench targets use [`Bench`] to run warmup + timed
//! iterations, report mean/median/σ and throughput, and optionally write a
//! CSV next to the binary. Timing uses `Instant`; a `black_box` shim
//! prevents the optimizer from deleting measured work.

use std::hint::black_box as std_black_box;
use std::time::{Duration, Instant};

/// Re-export of `std::hint::black_box` for bench bodies.
pub fn black_box<T>(x: T) -> T {
    std_black_box(x)
}

/// Statistics from one benchmark.
#[derive(Debug, Clone)]
pub struct BenchStats {
    /// Benchmark id.
    pub name: String,
    /// Timed iterations.
    pub iters: usize,
    /// Mean seconds per iteration.
    pub mean: f64,
    /// Median seconds per iteration.
    pub median: f64,
    /// Standard deviation.
    pub std: f64,
    /// Min / max seconds.
    pub min: f64,
    /// Max seconds.
    pub max: f64,
}

impl BenchStats {
    /// Human line, auto-scaled units.
    pub fn line(&self) -> String {
        format!(
            "{:<44} {:>12}/iter (median {:>12}, σ {:>10}, n={})",
            self.name,
            fmt_secs(self.mean),
            fmt_secs(self.median),
            fmt_secs(self.std),
            self.iters
        )
    }
}

/// Format seconds with an adaptive unit.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.3}s", s)
    }
}

/// A bench suite: runs closures, collects stats, prints a report.
pub struct Bench {
    /// Suite name (printed as a header).
    pub suite: String,
    /// Target time per benchmark.
    pub target: Duration,
    /// Collected stats.
    pub results: Vec<BenchStats>,
}

impl Bench {
    /// New suite with a per-benchmark time budget.
    pub fn new(suite: &str) -> Self {
        // honor SATURN_BENCH_FAST=1 for CI smoke runs
        let target = if std::env::var("SATURN_BENCH_FAST").is_ok() {
            Duration::from_millis(200)
        } else {
            Duration::from_secs(2)
        };
        println!("== bench suite: {suite} ==");
        Self { suite: suite.to_string(), target, results: Vec::new() }
    }

    /// Run one benchmark: `f` is called once per iteration.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchStats {
        // warmup + calibration
        let t0 = Instant::now();
        f();
        let first = t0.elapsed().as_secs_f64().max(1e-9);
        let warmup_iters = ((self.target.as_secs_f64() * 0.1 / first) as usize).clamp(1, 1000);
        for _ in 0..warmup_iters {
            f();
        }
        // timed runs
        let budget = self.target.as_secs_f64();
        let mut samples = Vec::new();
        let start = Instant::now();
        while start.elapsed().as_secs_f64() < budget || samples.len() < 5 {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
            if samples.len() >= 100_000 {
                break;
            }
        }
        samples.sort_by(f64::total_cmp);
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let median = samples[n / 2];
        let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n as f64;
        let stats = BenchStats {
            name: name.to_string(),
            iters: n,
            mean,
            median,
            std: var.sqrt(),
            min: samples[0],
            max: samples[n - 1],
        };
        println!("{}", stats.line());
        self.results.push(stats);
        self.results.last().unwrap()
    }

    /// Write results as CSV under `reports/bench_<suite>.csv`.
    pub fn write_csv(&self) -> std::io::Result<()> {
        let mut csv = String::from("name,iters,mean_s,median_s,std_s,min_s,max_s\n");
        for r in &self.results {
            csv.push_str(&format!(
                "{},{},{},{},{},{},{}\n",
                r.name, r.iters, r.mean, r.median, r.std, r.min, r.max
            ));
        }
        std::fs::create_dir_all("reports")?;
        std::fs::write(format!("reports/bench_{}.csv", self.suite), csv)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fmt_secs_units() {
        assert!(fmt_secs(5e-9).ends_with("ns"));
        assert!(fmt_secs(5e-6).ends_with("µs"));
        assert!(fmt_secs(5e-3).ends_with("ms"));
        assert!(fmt_secs(5.0).ends_with('s'));
    }

    #[test]
    fn bench_collects_stats() {
        std::env::set_var("SATURN_BENCH_FAST", "1");
        let mut b = Bench::new("selftest");
        b.target = Duration::from_millis(30);
        let mut acc = 0u64;
        b.bench("noop-ish", || {
            acc = black_box(acc.wrapping_add(1));
        });
        let s = &b.results[0];
        assert!(s.iters >= 5);
        assert!(s.mean >= 0.0 && s.min <= s.median && s.median <= s.max);
    }
}
