//! A minimal, dependency-free Rust lexer for `saturn-lint`.
//!
//! The rules in [`crate::lint::rules`] must match real tokens — never text
//! inside string literals or documentation. This lexer covers exactly the
//! surface that matters for that guarantee:
//!
//! - line comments (`//`, `///`, `//!`) and **nested** block comments;
//! - regular strings with escapes, raw strings (`r"…"`, `r#"…"#`, any hash
//!   depth), byte strings (`b"…"`), and raw byte strings (`br#"…"#`);
//! - char and byte-char literals (escapes included) vs lifetimes (`'a`,
//!   `'static`, `'_`);
//! - identifiers/keywords, numeric literals, and multi-character operators
//!   (`==`, `=>`, `::`, `<<=`, …) combined greedily so a lone `=` token
//!   really is an assignment.
//!
//! It is *not* a full Rust lexer: exotica such as raw identifiers
//! (`r#match`) lex as several adjacent tokens. That is harmless for
//! linting — every rule matches short, anchored token sequences — and
//! keeps the lexer small enough to be obviously correct. Numeric
//! literals are lexed whole, including underscores (`1_000`), radix
//! prefixes (`0x_FF`), suffixes (`1.5f64`), and signed exponents
//! (`1e-3`, `2.5E+10`); `0xE-3` stays a subtraction because radix
//! literals have no exponent.

/// Kind of a lexed token.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword.
    Ident,
    /// Operator / punctuation (multi-char operators are one token).
    Punct,
    /// Any string literal: regular, raw, byte, raw byte.
    Str,
    /// Char or byte-char literal.
    Char,
    /// Lifetime (`'a`, `'static`, `'_`).
    Lifetime,
    /// Numeric literal (integer or float, suffixes included).
    Num,
    /// `//`-style comment, text includes the slashes.
    LineComment,
    /// `/* … */` comment (nesting handled), text includes delimiters.
    BlockComment,
}

/// One lexed token with its 1-based source line.
#[derive(Debug, Clone)]
pub struct Token {
    /// Token kind.
    pub kind: TokKind,
    /// Source text of the token (comment text includes delimiters).
    pub text: String,
    /// 1-based line the token starts on.
    pub line: u32,
}

fn is_ident_start(c: u8) -> bool {
    c.is_ascii_alphabetic() || c == b'_'
}

fn is_ident_cont(c: u8) -> bool {
    c.is_ascii_alphanumeric() || c == b'_'
}

/// Multi-character operators, matched longest-first so `<<=` never lexes
/// as `<` `<` `=` and a bare `=` token is always an assignment.
const OPS3: [&str; 4] = ["<<=", ">>=", "..=", "..."];
const OPS2: [&str; 20] = [
    "==", "!=", "<=", ">=", "=>", "->", "+=", "-=", "*=", "/=", "%=", "^=", "&=", "|=", "&&",
    "||", "<<", ">>", "::", "..",
];

/// Count newlines in `s` (for multi-line literals/comments).
fn newlines(s: &str) -> u32 {
    s.bytes().filter(|&c| c == b'\n').count() as u32
}

/// Scan a quoted string starting at the opening `"` (index `i`), honoring
/// backslash escapes. Returns the index one past the closing quote.
fn scan_quoted(b: &[u8], i: usize) -> usize {
    let mut j = i + 1;
    while j < b.len() {
        match b[j] {
            b'\\' => j += 2,
            b'"' => return j + 1,
            _ => j += 1,
        }
    }
    b.len()
}

/// Scan a raw string whose opening quote is at `q` with `hashes` leading
/// `#` characters. Returns the index one past the final closing hash.
fn scan_raw(b: &[u8], q: usize, hashes: usize) -> usize {
    let mut j = q + 1;
    while j < b.len() {
        if b[j] == b'"'
            && j + 1 + hashes <= b.len()
            && b[j + 1..j + 1 + hashes].iter().all(|&c| c == b'#')
        {
            return j + 1 + hashes;
        }
        j += 1;
    }
    b.len()
}

/// Scan a char/byte-char literal starting at the opening `'` (index `i`).
/// Returns the index one past the closing quote.
fn scan_char(b: &[u8], i: usize) -> usize {
    let mut j = i + 1;
    while j < b.len() {
        match b[j] {
            b'\\' => j += 2,
            b'\'' => return j + 1,
            _ => j += 1,
        }
    }
    b.len()
}

/// Tokenize Rust source. Never panics on malformed input: unterminated
/// literals or comments run to end-of-file, unknown bytes are skipped.
pub fn tokenize(src: &str) -> Vec<Token> {
    let b = src.as_bytes();
    let n = b.len();
    let mut toks: Vec<Token> = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;

    let push = |toks: &mut Vec<Token>, kind: TokKind, text: &str, line: u32| {
        toks.push(Token { kind, text: text.to_string(), line });
    };

    while i < n {
        let c = b[i];
        if c == b'\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_ascii_whitespace() {
            i += 1;
            continue;
        }
        // comments (before operator matching so `//` is never `/` `/`)
        if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
            let start = i;
            while i < n && b[i] != b'\n' {
                i += 1;
            }
            push(&mut toks, TokKind::LineComment, &src[start..i], line);
            continue;
        }
        if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
            let start = i;
            let start_line = line;
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if b[i] == b'/' && i + 1 < n && b[i + 1] == b'*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == b'*' && i + 1 < n && b[i + 1] == b'/' {
                    depth -= 1;
                    i += 2;
                } else {
                    if b[i] == b'\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            push(&mut toks, TokKind::BlockComment, &src[start..i], start_line);
            continue;
        }
        // raw / byte string prefixes: r"…", r#"…"#, b"…", b'…', br#"…"#
        if c == b'r' || c == b'b' {
            let mut q = usize::MAX; // index of the opening quote, if raw
            let mut hashes = 0usize;
            let mut plain_quote = usize::MAX; // opening " of b"…"
            let mut byte_char = usize::MAX; // opening ' of b'…'
            if c == b'r' {
                let mut j = i + 1;
                while j < n && b[j] == b'#' {
                    j += 1;
                }
                if j < n && b[j] == b'"' {
                    hashes = j - (i + 1);
                    q = j;
                }
            } else {
                // c == b'b'
                if i + 1 < n && b[i + 1] == b'"' {
                    plain_quote = i + 1;
                } else if i + 1 < n && b[i + 1] == b'\'' {
                    byte_char = i + 1;
                } else if i + 1 < n && b[i + 1] == b'r' {
                    let mut j = i + 2;
                    while j < n && b[j] == b'#' {
                        j += 1;
                    }
                    if j < n && b[j] == b'"' {
                        hashes = j - (i + 2);
                        q = j;
                    }
                }
            }
            if q != usize::MAX {
                let end = scan_raw(b, q, hashes);
                let text = &src[i..end];
                push(&mut toks, TokKind::Str, text, line);
                line += newlines(text);
                i = end;
                continue;
            }
            if plain_quote != usize::MAX {
                let end = scan_quoted(b, plain_quote);
                let text = &src[i..end];
                push(&mut toks, TokKind::Str, text, line);
                line += newlines(text);
                i = end;
                continue;
            }
            if byte_char != usize::MAX {
                let end = scan_char(b, byte_char);
                push(&mut toks, TokKind::Char, &src[i..end], line);
                i = end;
                continue;
            }
            // falls through: ordinary identifier starting with r/b
        }
        if c == b'"' {
            let end = scan_quoted(b, i);
            let text = &src[i..end];
            push(&mut toks, TokKind::Str, text, line);
            line += newlines(text);
            i = end;
            continue;
        }
        if c == b'\'' {
            // lifetime or char literal: a single ident char closed by '
            // is a char ('a'); an ident run not closed by ' is a lifetime
            if i + 1 < n && is_ident_start(b[i + 1]) {
                let mut j = i + 1;
                while j < n && is_ident_cont(b[j]) {
                    j += 1;
                }
                let closed_single = j == i + 2 && j < n && b[j] == b'\'';
                if !closed_single {
                    push(&mut toks, TokKind::Lifetime, &src[i..j], line);
                    i = j;
                    continue;
                }
            }
            let end = scan_char(b, i);
            push(&mut toks, TokKind::Char, &src[i..end], line);
            i = end;
            continue;
        }
        if is_ident_start(c) {
            let start = i;
            while i < n && is_ident_cont(b[i]) {
                i += 1;
            }
            push(&mut toks, TokKind::Ident, &src[start..i], line);
            continue;
        }
        if c.is_ascii_digit() {
            let start = i;
            while i < n && is_ident_cont(b[i]) {
                i += 1;
            }
            // one fractional part: `1.5` but not the range in `0..5`
            if i + 1 < n && b[i] == b'.' && b[i + 1].is_ascii_digit() {
                i += 1;
                while i < n && is_ident_cont(b[i]) {
                    i += 1;
                }
            }
            // exponent with an explicit sign (`1e-3`, `2.5E+10`): the
            // unsigned form is already absorbed by the ident-cont runs;
            // radix-prefixed literals (`0xE-3`) must stay subtraction
            let radix = b[start] == b'0'
                && i > start + 1
                && matches!(b[start + 1], b'x' | b'X' | b'o' | b'O' | b'b' | b'B');
            if !radix
                && i < n
                && (b[i] == b'+' || b[i] == b'-')
                && (b[i - 1] == b'e' || b[i - 1] == b'E')
                && i + 1 < n
                && b[i + 1].is_ascii_digit()
            {
                i += 1;
                while i < n && is_ident_cont(b[i]) {
                    i += 1;
                }
            }
            push(&mut toks, TokKind::Num, &src[start..i], line);
            continue;
        }
        if c.is_ascii() {
            let rest = &src[i..];
            let mut matched = 0usize;
            for op in OPS3 {
                if rest.starts_with(op) {
                    matched = 3;
                    break;
                }
            }
            if matched == 0 {
                for op in OPS2 {
                    if rest.starts_with(op) {
                        matched = 2;
                        break;
                    }
                }
            }
            if matched == 0 {
                matched = 1;
            }
            push(&mut toks, TokKind::Punct, &src[i..i + matched], line);
            i += matched;
            continue;
        }
        // non-ASCII byte outside any literal (only ever seen in prose);
        // skip the whole UTF-8 sequence without emitting a token
        i += 1;
        while i < n && (b[i] & 0xC0) == 0x80 {
            i += 1;
        }
    }
    toks
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        tokenize(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn idents_puncts_and_numbers() {
        let ts = kinds("let x = a.b(1, 2.5);");
        let texts: Vec<&str> = ts.iter().map(|(_, s)| s.as_str()).collect();
        assert_eq!(texts, ["let", "x", "=", "a", ".", "b", "(", "1", ",", "2.5", ")", ";"]);
        assert_eq!(ts[0].0, TokKind::Ident);
        assert_eq!(ts[2].0, TokKind::Punct);
        assert_eq!(ts[9].0, TokKind::Num);
    }

    #[test]
    fn numeric_literals_lex_whole() {
        // underscores, signed exponents, radix prefixes: one token each
        let nums: Vec<String> = kinds("let a = 1_000; let b = 1e-3; let c = 0x_FF;")
            .into_iter()
            .filter(|(k, _)| *k == TokKind::Num)
            .map(|(_, s)| s)
            .collect();
        assert_eq!(nums, ["1_000", "1e-3", "0x_FF"]);
        let texts: Vec<String> =
            kinds("2.5E+10 1e3 7f64 1.5e-3f64").into_iter().map(|(_, s)| s).collect();
        assert_eq!(texts, ["2.5E+10", "1e3", "7f64", "1.5e-3f64"]);
        // radix literals have no exponent and ranges keep their operators
        let texts: Vec<String> = kinds("0xE-3 1-3 0..5").into_iter().map(|(_, s)| s).collect();
        assert_eq!(texts, ["0xE", "-", "3", "1", "-", "3", "0", "..", "5"]);
        // a trailing `e-` without a digit is not an exponent
        let texts: Vec<String> = kinds("1e- 3").into_iter().map(|(_, s)| s).collect();
        assert_eq!(texts, ["1e", "-", "3"]);
    }

    #[test]
    fn multichar_operators_are_single_tokens() {
        let texts: Vec<String> =
            kinds("a == b != c <= d >= e => f -> g :: h && i || j <<= k ..= l .. m")
                .into_iter()
                .filter(|(k, _)| *k == TokKind::Punct)
                .map(|(_, s)| s)
                .collect();
        assert_eq!(texts, ["==", "!=", "<=", ">=", "=>", "->", "::", "&&", "||", "<<=", "..=", ".."]);
        // a lone `=` still lexes as itself
        let eq: Vec<String> = kinds("x = 1")
            .into_iter()
            .filter(|(k, _)| *k == TokKind::Punct)
            .map(|(_, s)| s)
            .collect();
        assert_eq!(eq, ["="]);
    }

    #[test]
    fn strings_hide_their_contents() {
        // rule-relevant text inside a string must be one opaque Str token
        let ts = kinds(r#"let s = "Instant::now() .unwrap()";"#);
        assert_eq!(ts.iter().filter(|(k, _)| *k == TokKind::Str).count(), 1);
        assert!(ts.iter().all(|(k, s)| *k == TokKind::Str || !s.contains("unwrap")));
        // escaped quotes do not terminate the literal early
        let ts = kinds(r#"let s = "a \" b .unwrap() c";"#);
        assert_eq!(ts.iter().filter(|(k, _)| *k == TokKind::Str).count(), 1);
        assert!(!ts.iter().any(|(k, s)| *k == TokKind::Ident && s == "unwrap"));
    }

    #[test]
    fn raw_strings_any_hash_depth() {
        let src = "let a = r\"x .unwrap() y\"; let b = r#\"quote \" inside .expect(\"#; done";
        let ts = kinds(src);
        assert_eq!(ts.iter().filter(|(k, _)| *k == TokKind::Str).count(), 2);
        assert!(!ts.iter().any(|(k, s)| *k == TokKind::Ident && (s == "unwrap" || s == "expect")));
        assert!(ts.iter().any(|(k, s)| *k == TokKind::Ident && s == "done"));
        // deeper hash fences, with a "# that must not close the literal
        let src = "r##\"has \"# inside\"## after";
        let ts = kinds(src);
        assert_eq!(ts[0].0, TokKind::Str);
        assert!(ts[0].1.contains("inside"));
        assert_eq!(ts[1].1, "after");
    }

    #[test]
    fn byte_strings_and_byte_chars() {
        let ts = kinds("let a = b\"raw .unwrap() bytes\"; let c = b'x'; let r = br#\"more \" x\"#;");
        assert_eq!(ts.iter().filter(|(k, _)| *k == TokKind::Str).count(), 2);
        assert_eq!(ts.iter().filter(|(k, _)| *k == TokKind::Char).count(), 1);
        assert!(!ts.iter().any(|(k, s)| *k == TokKind::Ident && s == "unwrap"));
    }

    #[test]
    fn nested_block_comments() {
        let src = "before /* outer /* inner .unwrap() */ still comment */ after";
        let ts = kinds(src);
        assert_eq!(ts[0].1, "before");
        assert_eq!(ts[1].0, TokKind::BlockComment);
        assert!(ts[1].1.contains("inner"));
        assert_eq!(ts[2].1, "after");
        assert_eq!(ts.len(), 3);
    }

    #[test]
    fn line_comments_capture_to_eol() {
        let ts = tokenize("x // lint:allow(panic-freedom) -- why\ny");
        assert_eq!(ts[0].text, "x");
        assert_eq!(ts[1].kind, TokKind::LineComment);
        assert!(ts[1].text.contains("lint:allow"));
        assert_eq!(ts[2].text, "y");
        assert_eq!(ts[2].line, 2);
    }

    #[test]
    fn lifetimes_vs_char_literals() {
        let src = "fn f<'a>(x: &'a str) { let y = 'z'; let s = '\\''; let u = '\\u{41}'; let w = '_'; }";
        let ts = kinds(src);
        let lifetimes: Vec<&str> =
            ts.iter().filter(|(k, _)| *k == TokKind::Lifetime).map(|(_, s)| s.as_str()).collect();
        assert_eq!(lifetimes, ["'a", "'a"]);
        let chars: Vec<&str> =
            ts.iter().filter(|(k, _)| *k == TokKind::Char).map(|(_, s)| s.as_str()).collect();
        assert_eq!(chars, ["'z'", "'\\''", "'\\u{41}'", "'_'"]);
        // 'static is a lifetime, not a truncated char
        let ts = kinds("&'static str");
        assert!(ts.iter().any(|(k, s)| *k == TokKind::Lifetime && s == "'static"));
    }

    #[test]
    fn line_numbers_survive_multiline_tokens() {
        let src = "a\n/* two\nlines */\nb\nr\"raw\nstring\"\nc";
        let ts = tokenize(src);
        let find = |name: &str| ts.iter().find(|t| t.text == name).map(|t| t.line);
        assert_eq!(find("a"), Some(1));
        assert_eq!(find("b"), Some(4));
        assert_eq!(find("c"), Some(7));
    }

    #[test]
    fn unterminated_literals_do_not_panic() {
        // degenerate inputs lex to something rather than panicking
        for src in ["\"unterminated", "r#\"raw unterminated", "/* open comment", "'\\", "b\"open"] {
            let _ = tokenize(src);
        }
    }

    #[test]
    fn non_ascii_in_code_is_skipped() {
        // prose characters (§, ≥, →) appear in the tree's comments; the
        // lexer must also survive them outside literals
        let ts = kinds("a § b ≥ c");
        let idents: Vec<&String> =
            ts.iter().filter(|(k, _)| *k == TokKind::Ident).map(|(_, s)| s).collect();
        assert_eq!(idents, [&"a".to_string(), &"b".to_string(), &"c".to_string()]);
    }
}
