// Fixture: linted as `rust/src/solver/spase.rs` (rng-scoped).
// All randomness flows from the explicitly seeded DetRng; silent.

use crate::util::rng::DetRng;

pub fn draw(seed: u64, bound: u64) -> u64 {
    let mut rng = DetRng::new(seed);
    rng.below(bound)
}
