//! Cross-file propagation fixture, GOOD twin: the same public surface
//! as `xchain_helper_bad.rs` with order-stable, clock-free, RNG-free,
//! panic-free bodies. With this helper the whole twin set lints clean —
//! the chain findings come from the helper's bodies, not its callers.
pub fn now_secs() -> f64 {
    0.0
}

pub fn drain_unordered() -> f64 {
    let v: Vec<f64> = Vec::new();
    v.iter().sum()
}

pub fn pick_random() -> f64 {
    0.5
}

pub fn try_pop(xs: &[f64]) -> f64 {
    xs.first().copied().unwrap_or(0.0)
}
