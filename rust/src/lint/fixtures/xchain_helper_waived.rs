//! Cross-file propagation fixture, WAIVED twin: the bad bodies with a
//! justified `lint:allow` at every source site. A waiver at the source
//! suppresses every chain through it; deleting one (the meta-tests do)
//! must surface exactly that family's chain again.
use std::collections::HashMap;
use std::time::Instant;

pub fn now_secs() -> f64 {
    // lint:allow(clock-in-evaluator) -- fixture: pretend this feeds reporting only
    Instant::now().elapsed().as_secs_f64()
}

pub fn drain_unordered() -> f64 {
    let m: HashMap<u32, f64> = HashMap::new();
    // lint:allow(unordered-iteration) -- fixture: sum is a commutative exact fold
    m.values().sum()
}

pub fn pick_random() -> f64 {
    // lint:allow(ambient-rng) -- fixture: pretend the state never feeds a decision
    let _s = std::collections::hash_map::RandomState::new();
    0.5
}

pub fn try_pop(xs: &[f64]) -> f64 {
    // lint:allow(panic-freedom) -- fixture: pretend the caller guarantees non-empty
    *xs.first().unwrap()
}
