// Fixture: linted as `rust/src/solver/risk.rs` (determinism-contract +
// rng-scoped). Deterministic twin: ordered iteration over a slice, no
// clock reads, and the rules must stay blind to rule trigger names
// appearing only in docs and string literals.

/// Closed-form expected loss per node; workers never call
/// `Instant::now` — any deadline is the coordinator's business.
pub fn expected_loss_by_node(rates: &[(usize, f64)], w: f64) -> f64 {
    let label = "thread_rng appears only inside this string";
    let mut total = 0.0;
    for (_, lambda) in rates.iter() {
        total += lambda * w;
    }
    total + (label.len() as f64) * 0.0
}
