//! Cross-file propagation fixture: a determinism-contract entry point
//! (linted under the virtual path `rust/src/solver/delta.rs`) that calls
//! through a mid-module into shared helpers. The file itself is clean —
//! every violation in this twin set lives two hops away.
use crate::metrics::window_stats;

/// Contract entry: must stay clock/RNG/order-free *transitively*.
pub fn eval_move(xs: &[f64]) -> f64 {
    window_stats(xs)
}
