// Fixture: linted as `rust/src/online/mod.rs`.
// A waiver without a justification is a `waiver-syntax` finding, and a
// justified waiver that suppresses nothing is an `unused-waiver` finding.

// lint:allow(panic-freedom)
pub fn naked_waiver(g: Option<u32>) -> u32 {
    g.unwrap_or(0)
}

// lint:allow(panic-freedom) -- stale: the unwrap below was fixed long ago
pub fn stale_waiver(g: Option<u32>) -> u32 {
    g.unwrap_or(7)
}
