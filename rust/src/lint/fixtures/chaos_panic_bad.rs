// Fixture: linted as `rust/src/sim/chaos.rs` (panic-sensitive — the
// failure-handling path must degrade, never panic). Every line below
// that aborts on junk input must fire `panic-freedom`.

pub fn apply_event(alive: &mut Vec<bool>, node: Option<usize>, rate: Result<f64, String>) -> f64 {
    let n = node.unwrap();
    let slot = alive.get_mut(n).expect("event named a node the cluster does not have");
    *slot = false;
    match rate {
        Ok(r) if r > 0.0 => r,
        Ok(_) => panic!("non-positive slowdown rate"),
        Err(_) => unreachable!(),
    }
}
