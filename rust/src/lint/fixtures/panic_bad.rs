// Fixture: linted as `rust/src/online/mod.rs` (panic-sensitive).
// Every panic path below must fire `panic-freedom`.

pub fn admit(slot: Option<u32>, cfg: Result<u32, String>, kind: u8) -> u32 {
    let a = slot.unwrap();
    let b = cfg.expect("config must parse");
    match kind {
        0 => a + b,
        1 => panic!("unhandled kind"),
        2 => todo!(),
        3 => unimplemented!(),
        _ => unreachable!(),
    }
}
