// Fixture: linted as `rust/src/online/mod.rs`.
// A justified waiver directly above its finding suppresses it and is
// inventoried via --list-waivers; the file lints clean.

pub fn first(g: Option<u32>) -> u32 {
    // lint:allow(panic-freedom) -- fixture demo: the caller guarantees Some
    g.unwrap()
}
