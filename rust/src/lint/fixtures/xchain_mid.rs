//! Cross-file propagation fixture: the middle hop (linted under the
//! virtual path `rust/src/metrics/mod.rs` — no contract class of its
//! own). It merely forwards into `util::buf`; the chain pass must walk
//! through it without flagging anything here.
use crate::util::buf::{drain_unordered, now_secs, pick_random, try_pop};

pub fn window_stats(xs: &[f64]) -> f64 {
    let a = now_secs();
    let b = drain_unordered();
    let c = pick_random();
    let d = try_pop(xs);
    a + b + c + d
}
