// Fixture: linted as `rust/src/solver/risk.rs` (determinism-contract +
// rng-scoped). The expected-loss pricing below breaks all three
// contracts at once: a wall-clock read inside scoring, a HashMap-ordered
// accumulation (float sums are order-sensitive), and an ambient
// randomness source keying the hasher.

use std::collections::HashMap;

pub fn expected_loss_by_node(rates: &HashMap<usize, f64>, w: f64) -> f64 {
    let started = std::time::Instant::now();
    let mut total = 0.0;
    for (_, lambda) in rates.iter() {
        total += lambda * w;
    }
    let jitter = std::collections::hash_map::RandomState::new();
    let _ = (&started, &jitter);
    total
}
