//! Cross-file propagation fixture: a panic-sensitive entry point
//! (linted under the virtual path `rust/src/online/mod.rs`) reaching a
//! helper that unwraps. The panic-freedom chain must anchor at the
//! helper's unwrap, not here.
use crate::util::buf::try_pop;

pub fn ingest(xs: &[f64]) -> f64 {
    try_pop(xs)
}
