// Fixture: linted as `rust/src/sim/chaos.rs` (panic-sensitive). The
// same event application degrading instead of aborting: out-of-range
// nodes are ignored, junk rates clamp to a finite stall. Silent.

const STALL_RATE: f64 = 1e-9;

pub fn apply_event(alive: &mut [bool], node: Option<usize>, rate: Result<f64, String>) -> f64 {
    if let Some(slot) = node.and_then(|n| alive.get_mut(n)) {
        *slot = false;
    }
    match rate {
        Ok(r) if r.is_finite() && r > 0.0 => r,
        _ => STALL_RATE,
    }
}
