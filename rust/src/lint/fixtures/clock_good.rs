// Fixture: linted as `rust/src/solver/anneal.rs` (determinism-contract).
// Timing routed through the sanctioned util::DeadlinePoll, plus the rule
// must stay blind to `Instant::now` appearing in docs and string literals.

use crate::util::DeadlinePoll;

/// Workers never call `Instant::now` directly; see `util::Deadline`.
pub fn anneal_step(poll: &mut DeadlinePoll) -> bool {
    let label = "Instant::now is just prose inside this string";
    !poll.expired_batch() && !label.is_empty()
}
