//! Cross-file propagation fixture, BAD twin (linted under the virtual
//! path `rust/src/util/buf.rs` — no contract class): each helper hides
//! one violation that only the call-graph pass can see from the
//! contract entry points in `xchain_entry.rs` / `xchain_panic_entry.rs`.
use std::collections::HashMap;
use std::time::Instant;

pub fn now_secs() -> f64 {
    Instant::now().elapsed().as_secs_f64()
}

pub fn drain_unordered() -> f64 {
    let m: HashMap<u32, f64> = HashMap::new();
    m.values().sum()
}

pub fn pick_random() -> f64 {
    let _s = std::collections::hash_map::RandomState::new();
    0.5
}

pub fn try_pop(xs: &[f64]) -> f64 {
    *xs.first().unwrap()
}
