// Fixture: linted as `rust/src/solver/spase.rs` (rng-scoped).
// Every ambient randomness source below must fire `ambient-rng`.

pub fn jitter() -> u64 {
    let mut r = rand::thread_rng();
    let state = RandomState::new();
    let mut hasher = DefaultHasher::new();
    r.gen::<u64>() ^ probe(&state, &mut hasher)
}
