// Fixture: linted as `rust/src/sim/mod.rs` (determinism-contract).
// Keyed lookups into hash containers and iteration over ordered
// sequences are legal; nothing here may fire.

use std::collections::HashMap;

pub fn lookup(m: &HashMap<u64, f64>, ids: &[u64]) -> f64 {
    let mut acc = 0.0;
    for id in ids {
        if let Some(v) = m.get(id) {
            acc += *v;
        }
    }
    acc
}

pub fn upsert(m: &mut HashMap<u64, f64>, id: u64, v: f64) -> bool {
    *m.entry(id).or_insert(0.0) += v;
    m.contains_key(&id) && m.insert(id, v).is_some()
}
