// Fixture: linted as `rust/src/online/mod.rs` (panic-sensitive).
// The same logic with errors propagated via Result/anyhow; silent.
// `unwrap_or`-family helpers and fields *named* expect are not matches.

use anyhow::{anyhow, bail, Result};

pub fn admit(slot: Option<u32>, cfg: Result<u32>, kind: u8) -> Result<u32> {
    let a = slot.ok_or_else(|| anyhow!("no free slot"))?;
    let b = cfg?;
    if kind > 0 {
        bail!("unhandled kind {kind}");
    }
    Ok(a + b + slot.unwrap_or_default())
}
