// Fixture: linted as `rust/src/sim/mod.rs` (determinism-contract).
// Three distinct iteration shapes over hash containers, all of which
// must fire `unordered-iteration`: a method call on a typed param, a
// for-loop over a reference, and a chained map-returning call.

use std::collections::{HashMap, HashSet};

pub fn accumulate(m: &HashMap<u64, f64>, s: &HashSet<u64>, ctx: &Ctx) -> f64 {
    let mut acc = 0.0;
    for (_k, v) in m.iter() {
        acc += v;
    }
    for x in &s {
        acc += *x as f64;
    }
    let n = ctx.id_index_map().keys().count();
    acc + n as f64
}
