// Fixture: linted as `rust/src/solver/anneal.rs`.
// Mutations inside debug_assert! bodies vanish in release builds; both
// the `.push(` call and the bare `=` assignment must fire
// `debug-assert-side-effect`.

pub fn staged_replay(xs: &mut Vec<u64>, n: u64) {
    debug_assert!({
        xs.push(n);
        !xs.is_empty()
    });
    let mut verified = false;
    debug_assert!(verified = replay_matches(xs));
    drop(verified);
}
