// Fixture: linted as `rust/src/solver/anneal.rs`.
// Pure comparisons inside debug assertions are the sanctioned form;
// `==`/`<=`/`!=` are single comparison tokens, never assignment. Silent.

pub fn staged_replay(xs: &[u64], n: usize) {
    debug_assert!(xs.len() <= n && n != 0);
    debug_assert_eq!(xs.len(), n, "staging and replay disagree on {n}");
    debug_assert_ne!(xs.first(), None);
}
