// Fixture: linted as `rust/src/solver/anneal.rs` (determinism-contract).
// Both direct clock reads below must fire `clock-in-evaluator`.

pub fn evaluate_with_wall_clock(budget_ms: u64) -> bool {
    let start = std::time::Instant::now();
    let wall = std::time::SystemTime::now();
    (start.elapsed().as_millis() as u64) <= budget_ms && wall.elapsed().is_ok()
}
