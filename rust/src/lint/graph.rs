//! Conservative crate-level call graph for `saturn-lint` v2.
//!
//! Built from the item spans of [`crate::lint::items`], without `syn` or
//! type inference. Every call site in a fn body is classified into one
//! of five buckets:
//!
//! - **resolved** — an edge to one or more crate fns: free-fn calls
//!   through `use` aliases, `crate::`/`self::`/`super::` paths, glob
//!   imports, re-exports (`pub use inner::f;` in the owning module
//!   file), `Self::helper`, `Type::assoc_fn`, and method calls matched
//!   by name against every crate method (ambiguity keeps *all*
//!   candidates — over-approximation is the safe direction for taint);
//! - **external** — `std`/`core`/`alloc`/vendored-crate paths, prelude
//!   types, and well-known std method names with no crate-side
//!   definition;
//! - **ctor** — UpperCamel calls (tuple-struct/enum constructors);
//! - **local** — calls through closures or fn params bound in the same
//!   body (already covered by the per-file hit scan);
//! - **unresolved** — anything else. Unresolved sites produce no edge
//!   but are *counted*; CI pins the rate so resolution regressions
//!   surface instead of silently shrinking reachability.

use std::collections::{BTreeMap, BTreeSet};

use super::items::{local_callables, Item};
use super::lexer::{TokKind, Token};

/// Heads that always denote an external crate.
const EXTERNAL_HEADS: [&str; 5] = ["std", "core", "alloc", "anyhow", "xla"];

/// Prelude types/traits and primitives: `Vec::new`, `f64::max`, … are
/// external calls, never crate edges.
const PRELUDE_EXTERNAL: [&str; 46] = [
    "Some", "None", "Ok", "Err", "Box", "Vec", "String", "Option", "Result", "Default", "Clone",
    "Copy", "Drop", "From", "Into", "TryFrom", "TryInto", "Iterator", "IntoIterator",
    "DoubleEndedIterator", "ExactSizeIterator", "PartialEq", "PartialOrd", "Ord", "Eq", "ToString",
    "ToOwned", "AsRef", "AsMut", "FnOnce", "FnMut", "Fn", "Send", "Sync", "Sized", "f32", "f64",
    "u8", "u16", "u32", "u64", "u128", "usize", "i8", "i16", "i32",
];

/// Remaining primitive heads (split from [`PRELUDE_EXTERNAL`] only to
/// keep the array literals readable).
const PRELUDE_EXTERNAL_2: [&str; 6] = ["i64", "i128", "isize", "bool", "char", "str"];

fn is_prelude_external(name: &str) -> bool {
    PRELUDE_EXTERNAL.contains(&name) || PRELUDE_EXTERNAL_2.contains(&name)
}

/// std/prelude method names treated as external when no crate method of
/// the same name exists; a crate-defined method always wins over this
/// list.
const STD_METHODS: [&str; 328] = [
    "len", "is_empty", "push", "pop", "insert", "remove", "get", "get_mut", "contains",
    "contains_key", "entry", "clone", "to_string", "to_owned", "as_str", "as_ref", "as_mut",
    "as_slice", "as_bytes", "as_path", "iter", "iter_mut", "into_iter", "keys", "values", "drain",
    "map", "map_err", "and_then", "or_else", "unwrap", "unwrap_or", "unwrap_or_else",
    "unwrap_or_default", "expect", "ok_or", "ok_or_else", "filter", "filter_map", "collect",
    "fold", "sum", "product", "min", "max", "min_by", "max_by", "min_by_key", "max_by_key",
    "sort", "sort_by", "sort_by_key", "sort_unstable", "sort_unstable_by", "sort_unstable_by_key",
    "binary_search", "binary_search_by", "retain", "extend", "extend_from_slice", "truncate",
    "clear", "resize", "fill", "copy_within", "copy_from_slice", "clone_from_slice", "split_at",
    "split_at_mut", "chunks", "windows", "first", "last", "first_mut", "last_mut", "abs", "powi",
    "powf", "sqrt", "ln", "log2", "exp", "floor", "ceil", "round", "is_finite", "is_nan",
    "is_sign_negative", "is_some", "is_none", "is_ok", "is_err", "ok", "err", "take", "replace",
    "swap", "swap_remove", "rev", "zip", "enumerate", "chain", "any", "all", "find", "find_map",
    "position", "count", "nth", "skip", "step_by", "flat_map", "flatten", "cloned", "copied",
    "join", "split", "split_whitespace", "splitn", "trim", "trim_start", "trim_end",
    "starts_with", "ends_with", "strip_prefix", "strip_suffix", "parse", "chars", "bytes",
    "lines", "to_vec", "into", "try_into", "cmp", "partial_cmp", "eq", "ne", "lt", "le", "gt",
    "ge", "hash", "fmt", "write", "write_all", "writeln", "read", "read_to_string", "flush",
    "elapsed", "as_secs", "as_secs_f64", "as_millis", "from_secs", "from_secs_f64",
    "from_millis", "saturating_sub", "saturating_add", "saturating_mul", "checked_sub",
    "checked_add", "checked_mul", "checked_div", "wrapping_add", "wrapping_sub", "wrapping_mul",
    "rotate_left", "rotate_right", "to_le_bytes", "to_be_bytes", "from_le_bytes", "push_str",
    "repeat", "rem_euclid", "div_euclid", "signum", "clamp", "mul_add", "recip", "to_bits",
    "from_bits", "total_cmp", "then", "then_some", "then_with", "reserve", "dedup", "dedup_by",
    "dedup_by_key", "concat", "next", "next_back", "peek", "peekable", "by_ref", "take_while",
    "skip_while", "last_key_value", "or_insert", "or_insert_with", "or_default", "and_modify",
    "get_or_insert_with", "send", "recv", "try_recv", "lock", "spawn", "join_handle", "sleep",
    "store", "load", "fetch_add", "compare_exchange", "abs_diff", "unzip", "partition",
    "max_element", "is_dir", "is_file", "exists", "extension", "file_name", "file_stem",
    "display", "to_string_lossy", "to_path_buf", "read_dir", "metadata", "min_element",
    "subsec_nanos", "is_zero", "as_nanos", "abs_sub", "floor_char_boundary",
    "make_ascii_lowercase", "to_ascii_lowercase", "to_lowercase", "is_ascii", "is_ascii_digit",
    "is_ascii_alphabetic", "is_ascii_alphanumeric", "is_ascii_whitespace", "is_whitespace",
    "is_alphabetic", "is_alphanumeric", "is_digit", "is_numeric", "get_unchecked",
    "unchecked_add", "leading_zeros", "trailing_zeros", "count_ones", "pow", "is_power_of_two",
    "next_power_of_two", "is_char_boundary", "char_indices", "encode_utf8", "fract", "trunc",
    "try_fold", "try_for_each", "for_each", "inspect", "scan", "cycle", "is_match",
    "shrink_to_fit", "with_capacity", "capacity", "as_ptr", "as_mut_ptr", "offset", "add", "sub",
    "wait", "notify_all", "notify_one", "try_lock", "try_send", "recv_timeout", "set_len",
    "min_by_cached_key", "sort_by_cached_key", "rsplit", "rsplitn", "to_uppercase",
    "to_ascii_uppercase", "eq_ignore_ascii_case", "saturating_duration_since", "duration_since",
    "checked_duration_since", "default", "map_or", "map_or_else", "is_some_and", "is_none_or",
    "clone_from", "div_ceil", "partition_point", "with_context", "context", "split_once",
    "rsplit_once", "debug_struct", "field", "finish", "to_str", "as_deref", "as_deref_mut",
    "mul_f64", "div_f64", "or", "and", "xor", "wrapping_neg", "cos", "sin", "tan", "exp_m1",
    "ln_1p", "is_ascii_uppercase", "split_last", "append", "reverse",
    // vendored-xla surface (external crate; methods live outside rust/src)
    "reshape", "to_literal_sync", "to_tuple", "compile", "platform_name",
];

/// Identifiers that read like `name(` but are never calls.
const KEYWORDS_NOT_CALLS: [&str; 30] = [
    "if", "while", "for", "match", "return", "loop", "in", "as", "move", "else", "unsafe", "let",
    "mut", "ref", "fn", "impl", "trait", "mod", "use", "pub", "where", "struct", "enum", "union",
    "type", "const", "static", "await", "dyn", "box",
];

/// One fn node in the crate call graph.
#[derive(Debug, Clone)]
pub struct FnNode {
    /// Index of the owning file in the `units` slice passed to
    /// [`build_graph`].
    pub unit: usize,
    /// Full module path, inline mods included.
    pub module: Vec<String>,
    /// Enclosing `impl`/`trait` type, `None` for free fns.
    pub self_type: Option<String>,
    /// The fn's name.
    pub name: String,
    /// Token-index body span in the owning file's code tokens.
    pub body: (usize, usize),
    /// 1-based line span.
    pub lines: (u32, u32),
    /// Inside a `#[test]`/`#[cfg(test)]` exempt range.
    pub exempt: bool,
}

/// Per-file input to [`build_graph`]: the parsed structure of one
/// lib-crate file.
#[derive(Debug, Clone)]
pub struct FileUnit {
    /// Display path (repo-relative, `/`-separated).
    pub path: String,
    /// Crate-relative module path from [`super::items::module_path_of`].
    pub module: Vec<String>,
    /// Code tokens (comments stripped).
    pub code: Vec<Token>,
    /// Parsed fn items.
    pub items: Vec<Item>,
    /// use-alias → full segment path.
    pub uses: BTreeMap<String, Vec<String>>,
    /// Glob-import prefixes.
    pub globs: Vec<Vec<String>>,
    /// Test-exempt line ranges.
    pub exempt: Vec<(u32, u32)>,
}

/// Aggregate resolution statistics; CI pins the unresolved rate.
#[derive(Debug, Clone, Copy, Default)]
pub struct GraphStats {
    /// Non-exempt fns in the graph.
    pub functions: u64,
    /// Total call sites classified.
    pub call_sites: u64,
    /// Sites that produced at least one crate edge.
    pub resolved_calls: u64,
    /// Total crate edges (≥ resolved_calls; method ambiguity fans out).
    pub resolved_edges: u64,
    /// Sites classified external (std/prelude/vendored).
    pub external_calls: u64,
    /// UpperCamel constructor calls.
    pub ctor_calls: u64,
    /// Calls through body-local closures/params.
    pub local_calls: u64,
    /// Sites the resolver could not place (no edge, counted).
    pub unresolved_calls: u64,
    /// Method sites that matched more than one crate candidate.
    pub ambiguous_methods: u64,
}

impl GraphStats {
    /// unresolved_calls / call_sites (0 when there are no sites).
    pub fn unresolved_rate(&self) -> f64 {
        if self.call_sites == 0 {
            0.0
        } else {
            self.unresolved_calls as f64 / self.call_sites as f64
        }
    }
}

/// The crate call graph: fn nodes, adjacency, and resolution stats.
#[derive(Debug, Default)]
pub struct Graph {
    /// All fn nodes (exempt ones included, but edge-less and index-less).
    pub fns: Vec<FnNode>,
    /// Caller fn id → sorted callee fn ids.
    pub edges: BTreeMap<usize, Vec<usize>>,
    /// Resolution statistics.
    pub stats: GraphStats,
    free_index: BTreeMap<(Vec<String>, String), usize>,
    method_index: BTreeMap<String, Vec<usize>>,
    typed_method_index: BTreeMap<(Vec<String>, String, String), usize>,
    type_method_index: BTreeMap<(String, String), Vec<usize>>,
    modules: BTreeSet<Vec<String>>,
    top_modules: BTreeSet<String>,
    module_unit: BTreeMap<Vec<String>, usize>,
}

/// A classified call site.
enum CallSite {
    /// `[seg ::]* name (` — full segment list, callee name last.
    Path(Vec<String>),
    /// `. name (` — method name only.
    Method(String),
}

/// How one call site resolved.
enum Resolution {
    /// Crate edges to these fn ids.
    Resolved(Vec<usize>),
    /// std/prelude/vendored — outside the crate.
    External,
    /// UpperCamel constructor.
    Ctor,
    /// Call through a body-local closure or fn param.
    Local,
    /// Could not place; counted, no edge.
    Unresolved,
}

/// Where a normalized path head points.
enum Head {
    /// Crate-relative absolute segments.
    Crate(Vec<String>),
    /// External crate.
    External,
    /// Unknown head.
    Unknown,
}

fn ident(code: &[Token], i: usize, text: &str) -> bool {
    code.get(i).is_some_and(|t| t.kind == TokKind::Ident && t.text == text)
}

fn any_ident(code: &[Token], i: usize) -> Option<&str> {
    code.get(i).filter(|t| t.kind == TokKind::Ident).map(|t| t.text.as_str())
}

fn punct(code: &[Token], i: usize, text: &str) -> bool {
    code.get(i).is_some_and(|t| t.kind == TokKind::Punct && t.text == text)
}

fn is_upper_camel(name: &str) -> bool {
    name.chars().next().is_some_and(|c| c.is_ascii_uppercase())
}

/// Extract the call sites in `body` (inclusive `{`..`}` token indices).
fn call_sites(code: &[Token], body: (usize, usize)) -> Vec<CallSite> {
    let (a, b) = body;
    let mut out = Vec::new();
    let mut i = a;
    while i <= b && i < code.len() {
        let t = &code[i];
        // method call: `. name (` with an optional `::<…>` turbofish
        if t.kind == TokKind::Punct && t.text == "." {
            if let Some(m) = any_ident(code, i + 1) {
                let mut j = i + 2;
                if punct(code, j, "::") && punct(code, j + 1, "<") {
                    let mut angle = 0i32;
                    j += 1;
                    while j <= b && j < code.len() {
                        if code[j].kind == TokKind::Punct {
                            match code[j].text.as_str() {
                                "<" => angle += 1,
                                "<<" => angle += 2,
                                ">" => angle -= 1,
                                ">>" => angle -= 2,
                                _ => {}
                            }
                        }
                        j += 1;
                        if angle <= 0 {
                            break;
                        }
                    }
                }
                if punct(code, j, "(") {
                    out.push(CallSite::Method(m.to_string()));
                    i += 2;
                    continue;
                }
            }
            i += 1;
            continue;
        }
        // path or bare call: `[seg ::]* name (`
        if t.kind == TokKind::Ident
            && punct(code, i + 1, "(")
            && !KEYWORDS_NOT_CALLS.contains(&t.text.as_str())
        {
            // walk the path backwards
            let mut segs = vec![t.text.clone()];
            let mut j = i;
            while j >= 2
                && punct(code, j - 1, "::")
                && code.get(j - 2).is_some_and(|t2| t2.kind == TokKind::Ident)
            {
                segs.insert(0, code[j - 2].text.clone());
                j -= 2;
            }
            // a leading `.` means this is a method/turbofish chain,
            // handled above; `fn name(` is a definition, not a call
            if j >= 1 && (punct(code, j - 1, ".") || ident(code, j - 1, "fn")) {
                i += 1;
                continue;
            }
            out.push(CallSite::Path(segs));
        }
        i += 1;
    }
    out
}

/// Normalize a multi-segment path's head against the file's imports and
/// the crate module tree. `depth` guards alias cycles (`use x;` aliasing
/// itself) — real imports resolve in one or two hops.
fn normalize_head(g: &Graph, unit: &FileUnit, segs: &[String], depth: u32) -> Head {
    if depth > 8 {
        return Head::Unknown;
    }
    let Some(head) = segs.first() else { return Head::Unknown };
    let head = head.as_str();
    if head == "crate" || head == "saturn" {
        return Head::Crate(segs[1..].to_vec());
    }
    if head == "self" {
        let mut m = unit.module.clone();
        m.extend_from_slice(&segs[1..]);
        return Head::Crate(m);
    }
    if head == "super" {
        let mut m = unit.module.clone();
        let mut rest = segs;
        while rest.first().map(String::as_str) == Some("super") {
            m.pop();
            rest = &rest[1..];
        }
        m.extend_from_slice(rest);
        return Head::Crate(m);
    }
    if EXTERNAL_HEADS.contains(&head) {
        return Head::External;
    }
    if let Some(target) = unit.uses.get(head) {
        if target.first().is_some_and(|t| EXTERNAL_HEADS.contains(&t.as_str())) {
            return Head::External;
        }
        let mut joined = target.clone();
        joined.extend_from_slice(&segs[1..]);
        return match normalize_head(g, unit, &joined, depth + 1) {
            Head::Unknown => Head::Crate(joined),
            norm => norm,
        };
    }
    if g.top_modules.contains(head) {
        return Head::Crate(segs.to_vec());
    }
    let mut sibling = unit.module.clone();
    sibling.push(head.to_string());
    if g.modules.contains(&sibling) {
        // `sibling::f(…)` from a file whose module has a child `sibling`
        let mut m = unit.module.clone();
        m.extend_from_slice(segs);
        return Head::Crate(m);
    }
    if is_prelude_external(head) {
        return Head::External;
    }
    Head::Unknown
}

fn resolve_absolute(
    g: &Graph,
    units: &[FileUnit],
    unit: &FileUnit,
    item: &Item,
    segs: &[String],
    depth: u32,
) -> Resolution {
    if segs.len() == 1 {
        // a use-alias of a bare function name resolved to a single segment
        if let Some(&fid) = g.free_index.get(&(unit.module.clone(), segs[0].clone())) {
            return Resolution::Resolved(vec![fid]);
        }
        if is_upper_camel(&segs[0]) {
            return Resolution::Ctor;
        }
        return Resolution::Unresolved;
    }
    let head = segs[0].as_str();
    let name = segs[segs.len() - 1].clone();
    // `Self::helper(` — a method of the enclosing impl type
    if head == "Self" {
        if let Some(self_type) = &item.self_type {
            let mut full_mod = unit.module.clone();
            full_mod.extend(item.mods.iter().cloned());
            let key = (full_mod, self_type.clone(), name.clone());
            let fid = g.typed_method_index.get(&key).or_else(|| {
                g.typed_method_index.get(&(unit.module.clone(), self_type.clone(), name.clone()))
            });
            if let Some(&fid) = fid {
                return Resolution::Resolved(vec![fid]);
            }
            if is_upper_camel(&name) {
                return Resolution::Ctor;
            }
            if STD_METHODS.contains(&name.as_str()) {
                return Resolution::External; // e.g. derived `Self::default`
            }
            return Resolution::Unresolved;
        }
    }
    match normalize_head(g, unit, segs, 0) {
        Head::Unknown => {
            // `Type::method(` with the type defined (or imported) in this file
            if is_upper_camel(head) {
                let cands: Vec<usize> = g
                    .type_method_index
                    .get(&(head.to_string(), name.clone()))
                    .map(|v| v.iter().copied().filter(|&c| !g.fns[c].exempt).collect())
                    .unwrap_or_default();
                if segs.len() == 2 && !cands.is_empty() {
                    return Resolution::Resolved(cands);
                }
                if is_upper_camel(&name) {
                    return Resolution::Ctor;
                }
                if STD_METHODS.contains(&name.as_str()) && cands.is_empty() {
                    return Resolution::External;
                }
                if !cands.is_empty() {
                    return Resolution::Resolved(cands);
                }
            }
            if is_upper_camel(&name) {
                return Resolution::Ctor;
            }
            Resolution::Unresolved
        }
        Head::External => Resolution::External,
        Head::Crate(abs_segs) => {
            if abs_segs.is_empty() {
                return Resolution::Unresolved;
            }
            let name = abs_segs[abs_segs.len() - 1].clone();
            let prefix = abs_segs[..abs_segs.len() - 1].to_vec();
            if let Some(&fid) = g.free_index.get(&(prefix.clone(), name.clone())) {
                return Resolution::Resolved(vec![fid]);
            }
            // re-export: `mod::f` where `mod`'s own file says `pub use inner::f;`
            if depth < 4 {
                if let Some(&ou) = g.module_unit.get(&prefix) {
                    let owner = &units[ou];
                    if let Some(target) = owner.uses.get(&name) {
                        if *target != abs_segs {
                            return resolve_absolute(g, units, owner, item, target, depth + 1);
                        }
                    }
                }
            }
            if abs_segs.len() >= 2 {
                let ty = abs_segs[abs_segs.len() - 2].clone();
                let mod_prefix = abs_segs[..abs_segs.len() - 2].to_vec();
                if let Some(&fid) =
                    g.typed_method_index.get(&(mod_prefix, ty.clone(), name.clone()))
                {
                    return Resolution::Resolved(vec![fid]);
                }
                // type imported by alias: `DetRng::new` -> util::rng::DetRng::new
                let cands: Vec<usize> = g
                    .type_method_index
                    .get(&(ty, name.clone()))
                    .map(|v| v.iter().copied().filter(|&c| !g.fns[c].exempt).collect())
                    .unwrap_or_default();
                if !cands.is_empty() {
                    return Resolution::Resolved(cands);
                }
            }
            if is_upper_camel(&name) {
                return Resolution::Ctor;
            }
            if STD_METHODS.contains(&name.as_str()) {
                return Resolution::External;
            }
            Resolution::Unresolved
        }
    }
}

fn resolve_call(
    g: &Graph,
    units: &[FileUnit],
    unit: &FileUnit,
    item: &Item,
    site: &CallSite,
    locals: &BTreeSet<String>,
) -> Resolution {
    match site {
        CallSite::Method(name) => {
            let cands: Vec<usize> = g
                .method_index
                .get(name)
                .map(|v| v.iter().copied().filter(|&c| !g.fns[c].exempt).collect())
                .unwrap_or_default();
            if !cands.is_empty() {
                return Resolution::Resolved(cands);
            }
            if STD_METHODS.contains(&name.as_str()) {
                return Resolution::External;
            }
            Resolution::Unresolved
        }
        CallSite::Path(segs) if segs.len() == 1 => {
            let n = segs[0].as_str();
            let mut full_mod = unit.module.clone();
            full_mod.extend(item.mods.iter().cloned());
            let fid = g
                .free_index
                .get(&(full_mod, n.to_string()))
                .or_else(|| g.free_index.get(&(unit.module.clone(), n.to_string())));
            if let Some(&fid) = fid {
                return Resolution::Resolved(vec![fid]);
            }
            if let Some(target) = unit.uses.get(n) {
                return resolve_absolute(g, units, unit, item, target, 0);
            }
            for gl in &unit.globs {
                let mut joined = gl.clone();
                joined.push(n.to_string());
                if let Head::Crate(target) = normalize_head(g, unit, &joined, 0) {
                    if let Some((name, prefix)) = target.split_last() {
                        if let Some(&fid) = g.free_index.get(&(prefix.to_vec(), name.clone())) {
                            return Resolution::Resolved(vec![fid]);
                        }
                    }
                }
            }
            if locals.contains(n) {
                return Resolution::Local;
            }
            if is_upper_camel(n) {
                return Resolution::Ctor;
            }
            if n == "drop" {
                return Resolution::External;
            }
            Resolution::Unresolved
        }
        CallSite::Path(segs) => resolve_absolute(g, units, unit, item, segs, 0),
    }
}

/// Whether `line` falls inside any of the exempt ranges.
fn in_ranges(ranges: &[(u32, u32)], line: u32) -> bool {
    ranges.iter().any(|&(a, b)| a <= line && line <= b)
}

/// Build the crate call graph over the given file units.
pub fn build_graph(units: &[FileUnit]) -> Graph {
    let mut g = Graph::default();
    // first pass: fn nodes and name indexes
    let mut unit_fn_ids: Vec<Vec<usize>> = Vec::with_capacity(units.len());
    for (ui, unit) in units.iter().enumerate() {
        g.modules.insert(unit.module.clone());
        for k in 1..unit.module.len() {
            g.modules.insert(unit.module[..k].to_vec());
        }
        if let Some(top) = unit.module.first() {
            g.top_modules.insert(top.clone());
        }
        g.module_unit.entry(unit.module.clone()).or_insert(ui);
        let mut ids = Vec::with_capacity(unit.items.len());
        for it in &unit.items {
            let mut full_mod = unit.module.clone();
            full_mod.extend(it.mods.iter().cloned());
            let exempt = in_ranges(&unit.exempt, it.lines.0);
            let fid = g.fns.len();
            ids.push(fid);
            g.fns.push(FnNode {
                unit: ui,
                module: full_mod.clone(),
                self_type: it.self_type.clone(),
                name: it.name.clone(),
                body: it.body,
                lines: it.lines,
                exempt,
            });
            if exempt {
                continue;
            }
            g.modules.insert(full_mod.clone());
            match &it.self_type {
                None => {
                    g.free_index.entry((full_mod, it.name.clone())).or_insert(fid);
                }
                Some(ty) => {
                    g.method_index.entry(it.name.clone()).or_default().push(fid);
                    g.typed_method_index
                        .entry((full_mod, ty.clone(), it.name.clone()))
                        .or_insert(fid);
                    g.type_method_index
                        .entry((ty.clone(), it.name.clone()))
                        .or_default()
                        .push(fid);
                }
            }
        }
        unit_fn_ids.push(ids);
    }
    g.stats.functions = g.fns.iter().filter(|f| !f.exempt).count() as u64;
    // second pass: edges (resolution reads `g` immutably; accumulate
    // stats and adjacency on the side, then install them)
    let mut stats = g.stats;
    let mut edges: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
    for (ui, unit) in units.iter().enumerate() {
        for (it, &fid) in unit.items.iter().zip(&unit_fn_ids[ui]) {
            if g.fns[fid].exempt {
                continue;
            }
            let locals = local_callables(&unit.code, it);
            let mut callees: BTreeSet<usize> = BTreeSet::new();
            for site in call_sites(&unit.code, it.body) {
                stats.call_sites += 1;
                match resolve_call(&g, units, unit, it, &site, &locals) {
                    Resolution::Resolved(ids) => {
                        stats.resolved_calls += 1;
                        stats.resolved_edges += ids.len() as u64;
                        if ids.len() > 1 {
                            stats.ambiguous_methods += 1;
                        }
                        for cid in ids {
                            if cid != fid {
                                callees.insert(cid);
                            }
                        }
                    }
                    Resolution::External => stats.external_calls += 1,
                    Resolution::Ctor => stats.ctor_calls += 1,
                    Resolution::Local => stats.local_calls += 1,
                    Resolution::Unresolved => stats.unresolved_calls += 1,
                }
            }
            edges.insert(fid, callees.into_iter().collect());
        }
    }
    g.stats = stats;
    g.edges = edges;
    g
}

/// The id of the narrowest non-exempt fn in `unit` spanning `line`.
pub fn innermost_fn_at(g: &Graph, unit: usize, line: u32) -> Option<usize> {
    let mut best: Option<(usize, u32)> = None;
    for (fid, f) in g.fns.iter().enumerate() {
        if f.unit != unit || f.exempt {
            continue;
        }
        let (lo, hi) = f.lines;
        if lo <= line && line <= hi {
            let span = hi - lo;
            if best.map_or(true, |(_, s)| span < s) {
                best = Some((fid, span));
            }
        }
    }
    best.map(|(fid, _)| fid)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::items::{module_path_of, parse_items};
    use crate::lint::lexer::tokenize;

    fn unit(path: &str, src: &str) -> FileUnit {
        let code: Vec<Token> = tokenize(src)
            .into_iter()
            .filter(|t| t.kind != TokKind::LineComment && t.kind != TokKind::BlockComment)
            .collect();
        let (items, uses, globs) = parse_items(&code);
        FileUnit {
            path: path.to_string(),
            module: module_path_of(path).unwrap_or_default(),
            code,
            items,
            uses,
            globs,
            exempt: Vec::new(),
        }
    }

    #[test]
    fn resolution_classes_cover_edges_external_ctor_unresolved() {
        let units = vec![
            unit(
                "rust/src/solver/delta.rs",
                "use crate::util::buf::drain_helper;\n\
                 use crate::util::buf::Buf;\n\
                 pub fn eval_move(b: &mut Buf) { drain_helper(b); b.spill(); Buf::fresh(); }\n\
                 pub fn other() { crate::util::buf::free_fn(); let v = Vec::new(); v.len(); }\n",
            ),
            unit(
                "rust/src/util/buf.rs",
                "pub struct Buf;\n\
                 impl Buf {\n\
                     pub fn spill(&self) {}\n\
                     pub fn fresh() -> Self { Buf }\n\
                 }\n\
                 pub fn drain_helper(b: &mut Buf) {}\n\
                 pub fn free_fn() {}\n\
                 pub fn unknown_caller() { mystery_fn(); }\n",
            ),
        ];
        let g = build_graph(&units);
        let id = |name: &str| {
            g.fns.iter().position(|f| f.name == name).unwrap_or_else(|| panic!("fn {name}"))
        };
        let em = &g.edges[&id("eval_move")];
        assert!(em.contains(&id("drain_helper")), "use-alias free fn edge: {em:?}");
        assert!(em.contains(&id("spill")), "method-name edge: {em:?}");
        assert!(em.contains(&id("fresh")), "Type::assoc-fn edge via use alias: {em:?}");
        assert!(g.edges[&id("other")].contains(&id("free_fn")), "crate::-qualified edge");
        assert_eq!(g.stats.unresolved_calls, 1, "mystery_fn is the only unresolved site");
        assert!(g.stats.external_calls >= 2, "Vec::new + .len() counted external");
    }

    #[test]
    fn self_and_super_paths_resolve() {
        let units = vec![unit(
            "rust/src/sched/queue.rs",
            "pub struct Q;\n\
             impl Q {\n\
                 pub fn run(&self) { Self::step(); helper(); }\n\
                 fn step() {}\n\
             }\n\
             fn helper() { super::shared(); }\n",
        )];
        let mut units = units;
        units.push(unit("rust/src/sched/mod.rs", "pub fn shared() {}\n"));
        let g = build_graph(&units);
        let id = |name: &str| g.fns.iter().position(|f| f.name == name).expect("fn");
        assert!(g.edges[&id("run")].contains(&id("step")), "Self:: edge");
        assert!(g.edges[&id("run")].contains(&id("helper")), "bare free-fn edge");
        assert!(g.edges[&id("helper")].contains(&id("shared")), "super:: edge");
        assert_eq!(g.stats.unresolved_calls, 0);
    }

    #[test]
    fn test_exempt_fns_join_no_index() {
        let mut u = unit(
            "rust/src/util/buf.rs",
            "pub fn live() {}\n\
             fn test_helper() { live(); }\n",
        );
        u.exempt = vec![(2, 2)]; // pretend line 2 is inside #[cfg(test)]
        let g = build_graph(&[u]);
        let th = g.fns.iter().position(|f| f.name == "test_helper").expect("fn");
        assert!(g.fns[th].exempt);
        assert!(!g.edges.contains_key(&th), "exempt fns contribute no edges");
        assert_eq!(g.stats.functions, 1);
    }
}
