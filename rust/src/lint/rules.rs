//! The `saturn-lint` rules: token-sequence matchers over the output of
//! [`crate::lint::lexer`], scoped per file by the module classification in
//! [`crate::lint::classify`]. See `LINTS.md` for the catalogue — what each
//! rule guards, why, an example finding, and the waiver policy.

use super::lexer::{TokKind, Token};

/// `Instant::now`/`SystemTime::now` in a determinism-contract module.
pub const RULE_CLOCK: &str = "clock-in-evaluator";
/// Iteration over `HashMap`/`HashSet` in a determinism-contract module.
pub const RULE_UNORDERED: &str = "unordered-iteration";
/// Randomness source other than `util::rng::DetRng` in `solver`/`sim`.
pub const RULE_RNG: &str = "ambient-rng";
/// `unwrap`/`expect`/`panic!`-family in a panic-sensitive module.
pub const RULE_PANIC: &str = "panic-freedom";
/// Mutation inside a `debug_assert!` body (compiled out in release).
pub const RULE_DEBUG_ASSERT: &str = "debug-assert-side-effect";
/// Malformed waiver comment (missing justification, unknown rule).
pub const RULE_WAIVER_SYNTAX: &str = "waiver-syntax";
/// A waiver that suppresses nothing (stale after the code moved on).
pub const RULE_UNUSED_WAIVER: &str = "unused-waiver";
/// A file under `src/solver/`/`src/sim/` missing from the contract map.
pub const RULE_UNCLASSIFIED: &str = "unclassified-module";

/// Rules that may be waived with `// lint:allow(<rule>) -- <justification>`.
/// The two waiver meta-rules are deliberately not waivable.
pub const WAIVABLE_RULES: [&str; 5] =
    [RULE_CLOCK, RULE_UNORDERED, RULE_RNG, RULE_PANIC, RULE_DEBUG_ASSERT];

/// A rule match before waiver filtering.
#[derive(Debug, Clone)]
pub struct RawFinding {
    /// Which rule fired.
    pub rule: &'static str,
    /// 1-based source line.
    pub line: u32,
    /// The matched construct (`` `Instant::now` ``, `` `.unwrap()` `` …),
    /// used as the final hop of a call-chain label.
    pub what: String,
    /// Human-readable explanation.
    pub message: String,
}

fn ident(code: &[Token], i: usize, text: &str) -> bool {
    code.get(i).is_some_and(|t| t.kind == TokKind::Ident && t.text == text)
}

fn ident_of(code: &[Token], i: usize, texts: &[&str]) -> Option<String> {
    code.get(i)
        .filter(|t| t.kind == TokKind::Ident && texts.iter().any(|x| t.text == *x))
        .map(|t| t.text.clone())
}

fn any_ident(code: &[Token], i: usize) -> Option<&str> {
    code.get(i).filter(|t| t.kind == TokKind::Ident).map(|t| t.text.as_str())
}

fn punct(code: &[Token], i: usize, text: &str) -> bool {
    code.get(i).is_some_and(|t| t.kind == TokKind::Punct && t.text == text)
}

/// **clock-in-evaluator** — the PR 3 contract "workers never read the
/// clock", promoted from a comment to a check. Evaluator/worker code must
/// route all timing through `util::Deadline` / `util::DeadlinePoll`; a
/// direct `Instant::now`/`SystemTime::now` makes the search trajectory a
/// function of wall-clock jitter, breaking bit-identical replans.
pub fn check_clock(code: &[Token], out: &mut Vec<RawFinding>) {
    for i in 0..code.len() {
        if let Some(src) = ident_of(code, i, &["Instant", "SystemTime"]) {
            if punct(code, i + 1, "::") && ident(code, i + 2, "now") {
                out.push(RawFinding {
                    rule: RULE_CLOCK,
                    line: code[i].line,
                    what: format!("`{src}::now`"),
                    message: format!(
                        "`{src}::now` in a determinism-contract module; route timing \
                         through util::Deadline / util::DeadlinePoll (workers never \
                         read the clock)"
                    ),
                });
            }
        }
    }
}

/// Iterating methods that expose `HashMap`/`HashSet`'s nondeterministic
/// order. Keyed access (`get`, `entry`, `insert`, `contains_key`, …) is
/// deliberately absent: lookups are order-free and stay legal.
const ITER_METHODS: [&str; 9] = [
    "iter", "iter_mut", "into_iter", "keys", "into_keys", "values", "values_mut", "into_values",
    "drain",
];

/// Methods in this crate known to *return* a `HashMap`, so chained
/// iteration (`ctx.id_index_map().iter()`) is caught even without a
/// binding.
const MAP_RETURNING: [&str; 3] = ["id_index_map", "prior_index_map", "id_index"];

/// Collect identifiers bound to a `HashMap`/`HashSet` in this file: typed
/// bindings/fields/params (`name: [&][mut] [path::]HashMap<…>`) and
/// `let [mut] name = <expr containing HashMap::/HashSet:: or a known
/// map-returning method>`. File-scoped and flow-insensitive on purpose —
/// a rare same-name shadow costs a waiver, never a missed finding.
fn collect_map_names(code: &[Token]) -> Vec<String> {
    let mut names: Vec<String> = Vec::new();
    let mut add = |n: &str| {
        if !names.iter().any(|x| x == n) {
            names.push(n.to_string());
        }
    };
    for i in 0..code.len() {
        // `name : [&] [lifetime] [mut] [path ::]* (HashMap|HashSet)`
        if code[i].kind == TokKind::Ident && punct(code, i + 1, ":") {
            let mut j = i + 2;
            while punct(code, j, "&")
                || ident(code, j, "mut")
                || code.get(j).is_some_and(|t| t.kind == TokKind::Lifetime)
            {
                j += 1;
            }
            // walk a path `a :: b :: HashMap`
            while code.get(j).is_some_and(|t| t.kind == TokKind::Ident) && punct(code, j + 1, "::")
            {
                j += 2;
            }
            if ident_of(code, j, &["HashMap", "HashSet"]).is_some() {
                add(&code[i].text);
            }
        }
        // `let [mut] name = … HashMap:: … ;` / `… .id_index_map() … ;`
        if ident(code, i, "let") {
            let mut j = i + 1;
            if ident(code, j, "mut") {
                j += 1;
            }
            if code.get(j).map(|t| t.kind) != Some(TokKind::Ident) {
                continue;
            }
            let name = code[j].text.clone();
            if !punct(code, j + 1, "=") {
                continue;
            }
            let mut depth = 0i32;
            let mut k = j + 2;
            while k < code.len() {
                if code[k].kind == TokKind::Punct {
                    match code[k].text.as_str() {
                        "(" | "[" | "{" => depth += 1,
                        ")" | "]" | "}" => depth -= 1,
                        ";" if depth == 0 => break,
                        _ => {}
                    }
                }
                let from_ctor =
                    ident_of(code, k, &["HashMap", "HashSet"]).is_some() && punct(code, k + 1, "::");
                let from_method = ident_of(code, k, &MAP_RETURNING).is_some()
                    && punct(code, k + 1, "(")
                    && punct(code, k + 2, ")");
                if from_ctor || from_method {
                    add(&name);
                    break;
                }
                k += 1;
            }
        }
    }
    names
}

/// **unordered-iteration** — `HashMap`/`HashSet` iteration order is
/// seeded per process, so any contract-module decision derived from it
/// (candidate order, tie-breaks, accumulation order of floats) silently
/// breaks delta ≡ full-replay and thread-count trajectory parity. Keyed
/// lookups stay legal.
pub fn check_unordered(code: &[Token], out: &mut Vec<RawFinding>) {
    let maps = collect_map_names(code);
    let is_map = |n: &str| maps.iter().any(|m| m == n);
    let flag = |line: u32, what: &str, out: &mut Vec<RawFinding>| {
        out.push(RawFinding {
            rule: RULE_UNORDERED,
            line,
            what: what.to_string(),
            message: format!(
                "{what}: HashMap/HashSet iteration order is nondeterministic in a \
                 determinism-contract module; iterate a Vec/BTreeMap or sort first \
                 (keyed lookups are fine)"
            ),
        });
    };
    for i in 0..code.len() {
        // `name.iter()` / `self.name.drain()` / chained `id_index_map().keys()`
        if punct(code, i + 1, ".") {
            if let Some(m) = ident_of(code, i + 2, &ITER_METHODS) {
                if punct(code, i + 3, "(") {
                    if let Some(n) = any_ident(code, i) {
                        if is_map(n) {
                            flag(code[i].line, &format!("`{n}.{m}()`"), out);
                        }
                    }
                    // `…map_returning_method().iter()` — i is the `)` of a
                    // zero-arg call `name ( )`
                    if punct(code, i, ")") && i >= 2 && punct(code, i - 1, "(") {
                        if let Some(f) = any_ident(code, i - 2) {
                            if MAP_RETURNING.contains(&f) {
                                flag(code[i].line, &format!("`{f}().{m}()`"), out);
                            }
                        }
                    }
                }
            }
        }
        // `for pat in [&][mut] name {`
        if ident(code, i, "for") {
            let mut depth = 0i32;
            let mut j = i + 1;
            let limit = (i + 64).min(code.len());
            while j < limit {
                if code[j].kind == TokKind::Punct {
                    match code[j].text.as_str() {
                        "(" | "[" => depth += 1,
                        ")" | "]" => depth -= 1,
                        "{" | ";" => break, // not a for-loop header after all
                        _ => {}
                    }
                } else if depth == 0 && ident(code, j, "in") {
                    let mut k = j + 1;
                    while punct(code, k, "&") || ident(code, k, "mut") {
                        k += 1;
                    }
                    if let Some(n) = any_ident(code, k) {
                        if is_map(n) && punct(code, k + 1, "{") {
                            flag(code[k].line, &format!("`for … in {n}`"), out);
                        }
                    }
                    break;
                }
                j += 1;
            }
        }
    }
}

/// Identifiers whose presence means ambient (process-seeded or OS-seeded)
/// randomness: `rand`-crate entry points and std's randomly keyed hashers.
const RNG_IDENTS: [&str; 4] = ["thread_rng", "from_entropy", "RandomState", "DefaultHasher"];

/// **ambient-rng** — all randomness in `solver`/`sim` must flow from the
/// explicitly seeded `util::rng::DetRng`; an ambient generator (or a
/// randomly keyed hasher driving decisions) makes runs irreproducible and
/// voids every seed-pinned test margin.
pub fn check_rng(code: &[Token], out: &mut Vec<RawFinding>) {
    for i in 0..code.len() {
        let hit = if let Some(name) = ident_of(code, i, &RNG_IDENTS) {
            Some(name)
        } else if ident(code, i, "rand") && punct(code, i + 1, "::") {
            Some("rand::".to_string())
        } else {
            None
        };
        if let Some(name) = hit {
            out.push(RawFinding {
                rule: RULE_RNG,
                line: code[i].line,
                what: format!("`{name}`"),
                message: format!(
                    "`{name}` is an ambient randomness source; only util::rng::DetRng \
                     may produce randomness in solver/sim"
                ),
            });
        }
    }
}

/// **panic-freedom** — the online ingest path (`online`, `coordinator`)
/// fronts long-running streams; a panic tears down the whole coordinator.
/// Errors must propagate as `Result` (the vendored `anyhow` is in-tree).
pub fn check_panic(code: &[Token], out: &mut Vec<RawFinding>) {
    for i in 0..code.len() {
        if punct(code, i, ".") {
            if let Some(m) = ident_of(code, i + 1, &["unwrap", "expect"]) {
                if punct(code, i + 2, "(") {
                    out.push(RawFinding {
                        rule: RULE_PANIC,
                        line: code[i + 1].line,
                        what: format!("`.{m}()`"),
                        message: format!(
                            "`.{m}()` in a panic-sensitive module; propagate the error \
                             with Result/anyhow instead"
                        ),
                    });
                }
            }
        }
        if let Some(m) = ident_of(code, i, &["panic", "todo", "unimplemented", "unreachable"]) {
            if punct(code, i + 1, "!") {
                out.push(RawFinding {
                    rule: RULE_PANIC,
                    line: code[i].line,
                    what: format!("`{m}!`"),
                    message: format!(
                        "`{m}!` in a panic-sensitive module; propagate the error with \
                         Result/anyhow instead"
                    ),
                });
            }
        }
    }
}

/// **debug-assert-side-effect** — `debug_assert!` bodies vanish in
/// release builds, so a mutation inside one (the staging-replay
/// assertions in `anneal.rs` are the live risk) changes behavior between
/// profiles. Flags `.push(`/`.insert(` calls and bare `=` assignment
/// inside `debug_assert!`/`debug_assert_eq!`/`debug_assert_ne!` bodies.
pub fn check_debug_assert(code: &[Token], out: &mut Vec<RawFinding>) {
    let mut i = 0usize;
    while i < code.len() {
        let is_da = ident_of(code, i, &["debug_assert", "debug_assert_eq", "debug_assert_ne"])
            .is_some()
            && punct(code, i + 1, "!")
            && punct(code, i + 2, "(");
        if !is_da {
            i += 1;
            continue;
        }
        let macro_name = code[i].text.clone();
        let mut depth = 1i32;
        let mut j = i + 3;
        while j < code.len() && depth > 0 {
            if code[j].kind == TokKind::Punct {
                match code[j].text.as_str() {
                    "(" | "[" | "{" => depth += 1,
                    ")" | "]" | "}" => depth -= 1,
                    _ => {}
                }
                if depth == 0 {
                    break;
                }
                if code[j].text == "=" {
                    out.push(RawFinding {
                        rule: RULE_DEBUG_ASSERT,
                        line: code[j].line,
                        what: "`=`".to_string(),
                        message: format!(
                            "assignment inside `{macro_name}!` body; debug assertions \
                             are compiled out in release and must stay side-effect free"
                        ),
                    });
                }
            }
            if punct(code, j, ".") {
                if let Some(m) = ident_of(code, j + 1, &["push", "insert"]) {
                    if punct(code, j + 2, "(") {
                        out.push(RawFinding {
                            rule: RULE_DEBUG_ASSERT,
                            line: code[j + 1].line,
                            what: format!("`.{m}(`"),
                            message: format!(
                                "`.{m}(` inside `{macro_name}!` body; debug assertions \
                                 are compiled out in release and must stay side-effect \
                                 free"
                            ),
                        });
                    }
                }
            }
            j += 1;
        }
        i = j.max(i + 1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::lexer::tokenize;

    fn code_tokens(src: &str) -> Vec<Token> {
        tokenize(src)
            .into_iter()
            .filter(|t| t.kind != TokKind::LineComment && t.kind != TokKind::BlockComment)
            .collect()
    }

    #[test]
    fn map_name_collection_covers_bindings_fields_params() {
        let code = code_tokens(
            "struct S { cache: HashMap<u64, u32> }\n\
             fn f(id2idx: &HashMap<usize, usize>, xs: &[u32]) {\n\
                 let mut seen: std::collections::HashSet<u64> = Default::default();\n\
                 let by_id = HashMap::with_capacity(4);\n\
                 let widx = ctx.id_index_map();\n\
                 let plain = Vec::new();\n\
             }",
        );
        let names = collect_map_names(&code);
        for expect in ["cache", "id2idx", "seen", "by_id", "widx"] {
            assert!(names.iter().any(|n| n == expect), "missing {expect} in {names:?}");
        }
        assert!(!names.iter().any(|n| n == "plain" || n == "xs"));
    }

    #[test]
    fn unordered_flags_iteration_not_lookups() {
        let mut out = Vec::new();
        let code = code_tokens(
            "fn f(m: &HashMap<usize, usize>) {\n\
                 let v = m.get(&1);\n\
                 m.entry(2).or_insert(3);\n\
                 for (k, v) in m.iter() {}\n\
             }",
        );
        check_unordered(&code, &mut out);
        assert_eq!(out.len(), 1, "{out:?}");
        assert_eq!(out[0].rule, RULE_UNORDERED);
        assert_eq!(out[0].line, 4);
    }

    #[test]
    fn unordered_flags_for_loop_over_reference() {
        let mut out = Vec::new();
        let code =
            code_tokens("fn f() { let mut s = HashSet::new(); for x in &s { use_it(x); } }");
        check_unordered(&code, &mut out);
        assert_eq!(out.len(), 1, "{out:?}");
        // a for-loop over a Vec stays silent
        let mut out2 = Vec::new();
        let code2 = code_tokens("fn f() { let v = Vec::new(); for x in &v { use_it(x); } }");
        check_unordered(&code2, &mut out2);
        assert!(out2.is_empty(), "{out2:?}");
    }

    #[test]
    fn unordered_flags_chained_map_returning_call() {
        let mut out = Vec::new();
        let code = code_tokens("fn f(ctx: &PlanCtx) { for x in ctx.id_index_map().keys() {} }");
        check_unordered(&code, &mut out);
        assert_eq!(out.len(), 1, "{out:?}");
        // …but keyed use of the same return value is fine
        let mut out2 = Vec::new();
        let code2 = code_tokens("fn f(ctx: &PlanCtx) { let i = ctx.id_index_map()[&7]; }");
        check_unordered(&code2, &mut out2);
        assert!(out2.is_empty(), "{out2:?}");
    }

    #[test]
    fn clock_rule_matches_qualified_and_bare_paths() {
        let mut out = Vec::new();
        check_clock(
            &code_tokens("let t = std::time::Instant::now(); let s = SystemTime::now();"),
            &mut out,
        );
        assert_eq!(out.len(), 2, "{out:?}");
        // inside a string: invisible
        let mut out2 = Vec::new();
        check_clock(&code_tokens(r#"let s = "Instant::now";"#), &mut out2);
        assert!(out2.is_empty());
    }

    #[test]
    fn panic_rule_matches_all_five_forms() {
        let mut out = Vec::new();
        check_panic(
            &code_tokens(
                "fn f() { x.unwrap(); y.expect(\"m\"); panic!(\"p\"); todo!(); unreachable!(); }",
            ),
            &mut out,
        );
        assert_eq!(out.len(), 5, "{out:?}");
        // unwrap_or and a field named expect are not matches
        let mut out2 = Vec::new();
        check_panic(&code_tokens("fn f() { x.unwrap_or(0); s.expect = 1; }"), &mut out2);
        assert!(out2.is_empty(), "{out2:?}");
    }

    #[test]
    fn debug_assert_rule_flags_mutation_not_comparison() {
        let mut out = Vec::new();
        check_debug_assert(
            &code_tokens(
                "debug_assert!(a == b && c <= d);\n\
                 debug_assert_eq!(xs.len(), n, \"msg {n}\");\n\
                 debug_assert!({ v.push(1); v.len() > 0 });\n\
                 debug_assert!(x = compute());",
            ),
            &mut out,
        );
        assert_eq!(out.len(), 2, "{out:?}");
        assert!(out.iter().all(|f| f.rule == RULE_DEBUG_ASSERT));
        assert_eq!(out[0].line, 3);
        assert_eq!(out[1].line, 4);
    }

    #[test]
    fn rng_rule_flags_ambient_sources() {
        let mut out = Vec::new();
        check_rng(
            &code_tokens(
                "let r = rand::thread_rng();\n\
                 let h: RandomState = RandomState::new();\n\
                 let d = DetRng::new(7);",
            ),
            &mut out,
        );
        // rand:: + thread_rng on line 1, RandomState twice on line 2
        assert_eq!(out.len(), 4, "{out:?}");
        assert!(out.iter().all(|f| f.rule == RULE_RNG));
        let mut out2 = Vec::new();
        check_rng(&code_tokens("let d = DetRng::new(7); let x = d.below(10);"), &mut out2);
        assert!(out2.is_empty(), "{out2:?}");
    }
}
