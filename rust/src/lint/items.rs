//! Item-level parser for `saturn-lint` v2: the structural layer between
//! the raw token stream ([`crate::lint::lexer`]) and the call graph
//! ([`crate::lint::graph`]).
//!
//! One pass over a file's code tokens (comments already stripped)
//! recovers exactly what cross-file reachability needs, and nothing
//! more:
//!
//! - the **module tree**: inline `mod name { … }` nesting plus the
//!   file's own crate-relative path ([`module_path_of`]);
//! - **fn items** with their token-index body span and line span, the
//!   enclosing `impl`/`trait` type (so `Self::helper` and method-name
//!   resolution have a target), and the inline-mod path;
//! - **use declarations** resolved to segment lists: `{…}` groups are
//!   expanded, `as` aliases recorded under the alias, `self` in a group
//!   imports the parent, and `*` records a glob of the prefix.
//!
//! Spans come from token-level brace matching, never from text offsets,
//! so strings/comments can't unbalance them. The parser is conservative
//! by construction: a shape it does not recognize is skipped, which can
//! only make the call graph *miss* an edge — and every miss is visible
//! in the `--stats` unresolved-call count that CI pins.

use super::lexer::{TokKind, Token};

/// A parsed `fn` item with everything resolution needs.
#[derive(Debug, Clone)]
pub struct Item {
    /// The fn's name.
    pub name: String,
    /// Enclosing `impl`/`trait` type, `None` for free fns.
    pub self_type: Option<String>,
    /// Inline-mod path from the file root (e.g. `["tests"]`).
    pub mods: Vec<String>,
    /// Token-index range of the signature: one past `fn name`, up to the
    /// body `{`.
    pub sig: (usize, usize),
    /// Token-index range of the body: the `{` .. matching `}` inclusive.
    pub body: (usize, usize),
    /// 1-based line span: the `fn` keyword's line .. the closing brace's.
    pub lines: (u32, u32),
}

/// Crate-relative module path of a lib-crate file; `None` if the file is
/// not part of the library crate graph (bins, `main.rs`, tests, benches,
/// examples, lint fixtures).
pub fn module_path_of(path: &str) -> Option<Vec<String>> {
    let p = path.replace('\\', "/");
    if p.contains("lint/fixtures") {
        return None;
    }
    let idx = p.find("rust/src/")?;
    let rel = &p[idx + "rust/src/".len()..];
    if rel.starts_with("bin/") || rel == "main.rs" || !rel.ends_with(".rs") {
        return None;
    }
    let mut parts: Vec<String> =
        rel[..rel.len() - ".rs".len()].split('/').map(|s| s.to_string()).collect();
    if parts.last().map(String::as_str) == Some("mod") {
        parts.pop();
    } else if parts == ["lib"] {
        parts.clear();
    }
    Some(parts)
}

fn ident(code: &[Token], i: usize, text: &str) -> bool {
    code.get(i).is_some_and(|t| t.kind == TokKind::Ident && t.text == text)
}

fn any_ident(code: &[Token], i: usize) -> Option<&str> {
    code.get(i).filter(|t| t.kind == TokKind::Ident).map(|t| t.text.as_str())
}

fn punct(code: &[Token], i: usize, text: &str) -> bool {
    code.get(i).is_some_and(|t| t.kind == TokKind::Punct && t.text == text)
}

enum Scope {
    Mod(String),
    Impl(String),
    Trait(String),
    Fn(usize),
    Block,
}

/// Parse a file's code tokens into fn items, use-aliases, and globs.
///
/// `uses` maps each imported name (or `as` alias) to its full segment
/// list; `globs` holds the prefixes of `use path::*;` imports.
#[allow(clippy::type_complexity)]
pub fn parse_items(
    code: &[Token],
) -> (Vec<Item>, std::collections::BTreeMap<String, Vec<String>>, Vec<Vec<String>>) {
    let mut items: Vec<Item> = Vec::new();
    let mut uses = std::collections::BTreeMap::new();
    let mut globs = Vec::new();
    let mut stack: Vec<Scope> = Vec::new();
    let n = code.len();
    let mut i = 0usize;

    let mods = |stack: &[Scope]| -> Vec<String> {
        stack
            .iter()
            .filter_map(|s| if let Scope::Mod(m) = s { Some(m.clone()) } else { None })
            .collect()
    };
    let self_type = |stack: &[Scope]| -> Option<String> {
        stack.iter().rev().find_map(|s| match s {
            Scope::Impl(t) | Scope::Trait(t) => Some(t.clone()),
            _ => None,
        })
    };

    while i < n {
        let (kind, text, line) = (code[i].kind, code[i].text.as_str(), code[i].line);
        if kind == TokKind::Punct && text == "{" {
            stack.push(Scope::Block);
            i += 1;
            continue;
        }
        if kind == TokKind::Punct && text == "}" {
            if let Some(top) = stack.pop() {
                if let Scope::Fn(idx) = top {
                    items[idx].body.1 = i;
                    items[idx].lines.1 = line;
                }
            }
            i += 1;
            continue;
        }
        if kind == TokKind::Ident {
            if text == "use" {
                i = parse_use(code, i + 1, &mut uses, &mut globs);
                continue;
            }
            if text == "mod" {
                if let Some(name) = any_ident(code, i + 1) {
                    if punct(code, i + 2, "{") {
                        stack.push(Scope::Mod(name.to_string()));
                        i += 3;
                        continue;
                    }
                    if punct(code, i + 2, ";") {
                        i += 3;
                        continue;
                    }
                }
            }
            if text == "impl" || text == "trait" {
                // scan to the body `{` (or a terminating `;`), tracking
                // angle depth so generics never hide the type name
                let is_trait = text == "trait";
                let mut angle = 0i32;
                let mut j = i + 1;
                let mut type_idents: Vec<String> = Vec::new();
                let mut after_for: Option<usize> = None;
                let mut saw_where = false;
                while j < n {
                    let t = &code[j];
                    if t.kind == TokKind::Punct {
                        match t.text.as_str() {
                            "<" => angle += 1,
                            ">" => angle -= 1,
                            "<<" => angle += 2,
                            ">>" => angle -= 2,
                            "{" | ";" if angle <= 0 => break,
                            _ => {}
                        }
                    } else if t.kind == TokKind::Ident && angle <= 0 {
                        if t.text == "for" {
                            after_for = Some(type_idents.len());
                        } else if t.text == "where" {
                            saw_where = true;
                        } else if !saw_where {
                            type_idents.push(t.text.clone());
                        }
                    }
                    j += 1;
                }
                if j < n && code[j].text == "{" {
                    let ty = if is_trait {
                        type_idents.first().cloned()
                    } else if let Some(f) = after_for {
                        type_idents.get(f..).and_then(|t| t.last().cloned())
                    } else {
                        type_idents.last().cloned()
                    }
                    .unwrap_or_else(|| "?".to_string());
                    stack.push(if is_trait { Scope::Trait(ty) } else { Scope::Impl(ty) });
                }
                i = j + 1;
                continue;
            }
            if text == "fn" {
                if let Some(name) = any_ident(code, i + 1) {
                    let name = name.to_string();
                    let mut depth = 0i32;
                    let mut j = i + 2;
                    while j < n {
                        let t = &code[j];
                        if t.kind == TokKind::Punct {
                            match t.text.as_str() {
                                "(" | "[" => depth += 1,
                                ")" | "]" => depth -= 1,
                                "{" | ";" if depth == 0 => break,
                                _ => {}
                            }
                        }
                        j += 1;
                    }
                    if j < n && code[j].text == "{" {
                        items.push(Item {
                            name,
                            self_type: self_type(&stack),
                            mods: mods(&stack),
                            sig: (i + 2, j),
                            body: (j, j),
                            lines: (line, line),
                        });
                        stack.push(Scope::Fn(items.len() - 1));
                    }
                    i = j + 1;
                    continue;
                }
            }
        }
        i += 1;
    }
    (items, uses, globs)
}

/// Parse one use declaration starting after the `use` keyword; returns
/// the index one past the terminating `;`. Expands `{…}` groups and
/// records `as` aliases; `*` records a glob import of the prefix.
fn parse_use(
    code: &[Token],
    mut i: usize,
    uses: &mut std::collections::BTreeMap<String, Vec<String>>,
    globs: &mut Vec<Vec<String>>,
) -> usize {
    let n = code.len();

    fn record(uses: &mut std::collections::BTreeMap<String, Vec<String>>, segs: Vec<String>) {
        if segs.len() >= 2 && segs.last().map(String::as_str) == Some("self") {
            // `use a::b::{self, C}` imports `b` itself under its own name
            let parent = segs[..segs.len() - 1].to_vec();
            uses.insert(segs[segs.len() - 2].clone(), parent);
        } else if let Some(last) = segs.last() {
            uses.insert(last.clone(), segs.clone());
        }
    }

    fn parse_tree(
        code: &[Token],
        mut i: usize,
        prefix: &[String],
        uses: &mut std::collections::BTreeMap<String, Vec<String>>,
        globs: &mut Vec<Vec<String>>,
    ) -> usize {
        let n = code.len();
        let mut segs: Vec<String> = prefix.to_vec();
        while i < n {
            let t = &code[i];
            if t.kind == TokKind::Ident && t.text == "as" {
                if let Some(alias) = any_ident(code, i + 1) {
                    uses.insert(alias.to_string(), segs);
                    return i + 2;
                }
            }
            if t.kind == TokKind::Ident || t.kind == TokKind::Num {
                segs.push(t.text.clone());
                i += 1;
                continue;
            }
            if t.kind == TokKind::Punct && t.text == "::" {
                i += 1;
                continue;
            }
            if t.kind == TokKind::Punct && t.text == "{" {
                i += 1;
                while i < n && !punct(code, i, "}") {
                    i = parse_tree(code, i, &segs, uses, globs);
                    if punct(code, i, ",") {
                        i += 1;
                    }
                }
                return i + 1;
            }
            if t.kind == TokKind::Punct && t.text == "*" {
                globs.push(segs);
                return i + 1;
            }
            break;
        }
        record(uses, segs);
        i
    }

    while i < n && !punct(code, i, ";") {
        i = parse_tree(code, i, &[], uses, globs);
        if i < n && punct(code, i, ",") {
            i += 1;
        } else if i < n && !punct(code, i, ";") {
            i += 1;
        }
    }
    i + 1
}

/// Names that can shadow free fns inside `item`'s body: parameter names
/// from the signature, `let`-bound locals (closures included),
/// destructuring patterns, and match-arm ctor patterns (`Some(f) => …`).
/// Calls through them stay inside the enclosing fn's body, which the
/// per-file hit scan already covers — no edge, no unresolved count.
pub fn local_callables(code: &[Token], item: &Item) -> std::collections::BTreeSet<String> {
    let mut names = std::collections::BTreeSet::new();
    let (lo, hi) = item.sig;
    let mut depth = 0i32;
    for k in lo..hi.min(code.len()) {
        if code[k].kind == TokKind::Punct {
            match code[k].text.as_str() {
                "(" | "[" | "{" => depth += 1,
                ")" | "]" | "}" => depth -= 1,
                _ => {}
            }
        } else if depth >= 1 && code[k].kind == TokKind::Ident && punct(code, k + 1, ":") {
            names.insert(code[k].text.clone());
        }
    }
    let (a, b) = item.body;
    for k in a..(b + 1).min(code.len()) {
        if ident(code, k, "let") {
            let mut j = k + 1;
            if ident(code, j, "mut") {
                j += 1;
            }
            let head = any_ident(code, j).map(|s| s.to_string());
            if let Some(ref name) = head {
                if punct(code, j + 1, "=") {
                    names.insert(name.clone());
                    continue;
                }
            }
            // destructuring pattern: `let Some(f) =`, `let (a, b) =`
            if head.is_some() {
                j += 1; // ctor name
            }
            if punct(code, j, "(") {
                let mut depth2 = 1i32;
                j += 1;
                while j < code.len() && depth2 > 0 {
                    if code[j].kind == TokKind::Punct && code[j].text == "(" {
                        depth2 += 1;
                    } else if code[j].kind == TokKind::Punct && code[j].text == ")" {
                        depth2 -= 1;
                    } else if let Some(n3) = any_ident(code, j) {
                        if n3 != "mut" {
                            names.insert(n3.to_string());
                        }
                    }
                    j += 1;
                }
            }
        }
        // match-arm ctor pattern: `Some(f) => …` binds `f`
        if code[k].kind == TokKind::Ident && punct(code, k + 1, "(") {
            let mut depth2 = 1i32;
            let mut j = k + 2;
            let mut inner: Vec<String> = Vec::new();
            while j < (b + 1).min(code.len()) && depth2 > 0 {
                if code[j].kind == TokKind::Punct && code[j].text == "(" {
                    depth2 += 1;
                } else if code[j].kind == TokKind::Punct && code[j].text == ")" {
                    depth2 -= 1;
                } else if let Some(n3) = any_ident(code, j) {
                    if n3 != "mut" {
                        inner.push(n3.to_string());
                    }
                }
                j += 1;
            }
            if punct(code, j, "=>") {
                names.extend(inner);
            }
        }
    }
    names
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lint::lexer::tokenize;

    fn code_tokens(src: &str) -> Vec<Token> {
        tokenize(src)
            .into_iter()
            .filter(|t| t.kind != TokKind::LineComment && t.kind != TokKind::BlockComment)
            .collect()
    }

    #[test]
    fn module_paths_follow_crate_layout() {
        assert_eq!(module_path_of("rust/src/util/mod.rs"), Some(vec!["util".to_string()]));
        assert_eq!(
            module_path_of("rust/src/sim/chaos.rs"),
            Some(vec!["sim".to_string(), "chaos".to_string()])
        );
        assert_eq!(module_path_of("rust/src/lib.rs"), Some(vec![]));
        assert_eq!(module_path_of("rust/src/bin/saturn_lint.rs"), None);
        assert_eq!(module_path_of("rust/tests/prop_invariants.rs"), None);
        assert_eq!(module_path_of("rust/src/lint/fixtures/xchain_entry.rs"), None);
    }

    #[test]
    fn fn_items_record_impl_type_and_inline_mods() {
        let code = code_tokens(
            "pub fn top(x: u32) -> u32 { helper(x) }\n\
             fn helper(x: u32) -> u32 { x + 1 }\n\
             impl<'a> Kernel<'a> {\n\
                 pub fn eval(&self) -> f64 { self.score() }\n\
                 fn score(&self) -> f64 { 0.0 }\n\
             }\n\
             impl fmt::Display for Finding {\n\
                 fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result { write!(f, \"x\") }\n\
             }\n\
             mod inner { pub fn leaf() {} }",
        );
        let (items, _, _) = parse_items(&code);
        let sig = |name: &str| {
            items
                .iter()
                .find(|it| it.name == name)
                .map(|it| (it.self_type.clone(), it.mods.clone()))
        };
        assert_eq!(sig("top"), Some((None, vec![])));
        assert_eq!(sig("eval"), Some((Some("Kernel".to_string()), vec![])));
        assert_eq!(sig("fmt"), Some((Some("Finding".to_string()), vec![])));
        assert_eq!(sig("leaf"), Some((None, vec!["inner".to_string()])));
        // body line spans cover the whole fn
        let top = items.iter().find(|it| it.name == "top").expect("top parsed");
        assert_eq!(top.lines, (1, 1));
    }

    #[test]
    fn use_declarations_resolve_groups_aliases_and_globs() {
        let code = code_tokens(
            "use crate::util::rng::DetRng;\n\
             use std::collections::{HashMap, HashSet};\n\
             use crate::solver::risk as risk_mod;\n\
             use crate::sched::{self, Schedule};\n\
             use crate::model::*;\n",
        );
        let (_, uses, globs) = parse_items(&code);
        let path = |alias: &str| uses.get(alias).map(|v| v.join("::"));
        assert_eq!(path("DetRng"), Some("crate::util::rng::DetRng".to_string()));
        assert_eq!(path("HashMap"), Some("std::collections::HashMap".to_string()));
        assert_eq!(path("HashSet"), Some("std::collections::HashSet".to_string()));
        assert_eq!(path("risk_mod"), Some("crate::solver::risk".to_string()));
        assert_eq!(path("sched"), Some("crate::sched".to_string()));
        assert_eq!(path("Schedule"), Some("crate::sched::Schedule".to_string()));
        assert_eq!(globs, vec![vec!["crate".to_string(), "model".to_string()]]);
    }

    #[test]
    fn local_callables_cover_params_lets_and_match_arms() {
        let code = code_tokens(
            "fn f(cb: impl Fn(u32) -> u32, x: u32) -> u32 {\n\
                 let g = |y: u32| y + 1;\n\
                 let Some(h) = maybe() else { return 0 };\n\
                 match pick() { Some(k) => k(x), None => cb(g(h(x))) }\n\
             }",
        );
        let (items, _, _) = parse_items(&code);
        let locals = local_callables(&code, &items[0]);
        for name in ["cb", "g", "h", "k"] {
            assert!(locals.contains(name), "missing {name} in {locals:?}");
        }
    }
}
