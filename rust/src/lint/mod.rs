//! `saturn-lint` — a dependency-free static analyzer enforcing the repo's
//! determinism and panic-freedom contracts at CI time.
//!
//! The annealer's two core contracts — delta ≡ full-replay and
//! bit-identical trajectories for every thread count — plus the online
//! path's panic-freedom are checked *dynamically* by property tests, which
//! catch a stray `Instant::now`, an ambient RNG draw, or a `HashMap`
//! iteration only probabilistically and long after the offending line
//! landed. This module checks them *statically*: a minimal Rust lexer
//! ([`lexer`]) feeds token-sequence rules ([`rules`]) scoped by a per-file
//! module classification ([`classify`]), so rules match real tokens, never
//! text inside strings or docs, and `#[cfg(test)]`/`#[test]` regions (and
//! `tests/`/`benches/` trees) are exempt.
//!
//! # v2: crate-wide call-graph taint analysis
//!
//! Per-file scanning misses the laundered violation: a contract fn that
//! calls a helper in a *non*-contract file which reads the clock, draws
//! ambient randomness, iterates a `HashMap`, or unwraps. v2 re-expresses
//! each contract rule as source/sink reachability over a conservative
//! crate call graph ([`items`] parses fn items and imports, [`graph`]
//! builds best-effort edges): entry points are the non-test fns of
//! contract-classified files, and any rule hit inside a fn *reachable*
//! from them — wherever it lives — is a finding, reported with the full
//! call chain (`solver/delta.rs::eval_move → util/buf.rs::drain_unordered
//! → HashMap::iter`) and anchored at the source site so the fix location
//! is unambiguous. A waiver at the source fn waives every chain through
//! it. Two meta-rules ride along: `unclassified-module` (a new file under
//! `src/solver/`/`src/sim/` missing from the contract map — unwaivable)
//! and the CI-pinned unresolved-call-rate (resolution regressions fail
//! the build instead of silently shrinking reachability).
//!
//! Run it as `cargo run --release --bin saturn-lint` (CI does, with
//! `--format json` uploaded as an artifact), or call [`lint_tree`] /
//! [`lint_files`] / [`lint_source`] directly. See `LINTS.md` for the
//! rule catalogue.
//!
//! # Waivers
//!
//! A finding can be waived with a justified inline comment on the same
//! line or the line directly above the offending code:
//!
//! ```text
//! // lint:allow(clock-in-evaluator) -- coordinator-side budget start,
//! //                                   never read by workers
//! ```
//!
//! The justification after `--` is mandatory — a bare waiver is itself a
//! finding (`waiver-syntax`), as is a waiver that no longer suppresses
//! anything (`unused-waiver`) or one naming an unknown rule. Waivers are
//! only recognized in plain `//` comments (never `///`/`//!` docs, so
//! documenting the syntax cannot accidentally waive). Inventory them with
//! `saturn-lint --list-waivers`.

pub mod graph;
pub mod items;
pub mod lexer;
pub mod rules;

use self::graph::{build_graph, innermost_fn_at, FileUnit, GraphStats};
use self::items::{module_path_of, parse_items};
use self::lexer::{tokenize, TokKind, Token};
use self::rules::{
    check_clock, check_debug_assert, check_panic, check_rng, check_unordered, RawFinding,
    RULE_CLOCK, RULE_PANIC, RULE_RNG, RULE_UNCLASSIFIED, RULE_UNORDERED, RULE_UNUSED_WAIVER,
    RULE_WAIVER_SYNTAX, WAIVABLE_RULES,
};
use std::fmt;
use std::path::{Path, PathBuf};

/// The roots CI lints, relative to the repository root.
pub const DEFAULT_ROOTS: [&str; 4] = ["rust/src", "rust/benches", "rust/tests", "examples"];

/// Determinism-contract files: the delta kernel, the speculative anneal
/// engine, the objective layer, the optimizer driving both, the planning
/// context they all read, the expected-loss risk pricing scored inside
/// every evaluator, and the simulator's indexed event queue (whose
/// ordering and tie-breaks pin the byte-identity of every sim run).
/// Together with `src/sim/` these are the modules where delta ≡
/// full-replay and thread-count trajectory parity must hold bit-for-bit.
const DETERMINISM_FILES: [&str; 7] = [
    "src/solver/delta.rs",
    "src/solver/anneal.rs",
    "src/solver/objective.rs",
    "src/solver/joint.rs",
    "src/solver/policy.rs",
    "src/solver/risk.rs",
    "src/sim/events.rs",
];

/// Files under `src/solver/`/`src/sim/` that are *deliberately* outside
/// the determinism contract (entry shims, the offline MILP/LP reference
/// solvers, the sim driver, the chaos generator — each is covered by
/// `src/sim/`-wide classification or carries its own class). Every other
/// file under those roots must appear in [`DETERMINISM_FILES`] or here,
/// or the `unclassified-module` meta-rule fires: a new solver/sim module
/// must be classified *explicitly*, never silently unchecked.
const KNOWN_NON_CONTRACT: [&str; 6] = [
    "src/solver/mod.rs",
    "src/solver/spase.rs",
    "src/solver/milp.rs",
    "src/solver/lp.rs",
    "src/sim/mod.rs",
    "src/sim/chaos.rs",
];

/// Which rule families apply to a file, derived from its path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FileClass {
    /// Determinism-contract module: clock + unordered-iteration rules.
    pub determinism: bool,
    /// Inside `solver`/`sim`: the ambient-rng rule.
    pub rng_scope: bool,
    /// Online ingest path (`online`, `coordinator`) and the simulator's
    /// chaos state machine (`sim/chaos.rs` — the failure-handling path
    /// must degrade, never panic): panic-freedom rule.
    pub panic_sensitive: bool,
    /// `tests/` or `benches/` tree: all rules exempt (waivers still
    /// parsed so malformed ones are reported).
    pub test_only: bool,
}

/// Classify a repo-relative path (`rust/src/solver/delta.rs`, …).
pub fn classify(path: &str) -> FileClass {
    let p = path.replace('\\', "/");
    let test_only = p.contains("/tests/")
        || p.starts_with("tests/")
        || p.contains("/benches/")
        || p.starts_with("benches/");
    let determinism = DETERMINISM_FILES.iter().any(|s| p.ends_with(s)) || p.contains("src/sim/");
    FileClass {
        determinism,
        rng_scope: p.contains("src/solver/") || p.contains("src/sim/"),
        panic_sensitive: p.contains("src/online/")
            || p.contains("src/coordinator/")
            || p.ends_with("src/sim/chaos.rs"),
        test_only,
    }
}

/// One reported lint violation.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Repo-relative path.
    pub path: String,
    /// 1-based line.
    pub line: u32,
    /// Rule identifier (see [`rules`]).
    pub rule: &'static str,
    /// Explanation of the violation.
    pub message: String,
    /// For cross-file findings: the call chain from a contract entry
    /// point to the source site (`path::fn` labels, hit token last).
    /// Empty for direct (same-file) findings.
    pub chain: Vec<String>,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.path, self.line, self.rule, self.message)
    }
}

/// One parsed `lint:allow` waiver.
#[derive(Debug, Clone)]
pub struct Waiver {
    /// Repo-relative path.
    pub path: String,
    /// 1-based line of the waiver comment.
    pub line: u32,
    /// Rules the waiver covers.
    pub rules: Vec<String>,
    /// The mandatory justification after `--`.
    pub justification: String,
    /// Whether the waiver suppressed at least one hit (direct or chain).
    pub used: bool,
}

impl fmt::Display for Waiver {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: {} -- {}", self.path, self.line, self.rules.join(", "), self.justification)
    }
}

/// Lint result for one file.
#[derive(Debug, Clone, Default)]
pub struct FileReport {
    /// Findings after waiver filtering, sorted by line.
    pub findings: Vec<Finding>,
    /// All waivers in the file (used or not).
    pub waivers: Vec<Waiver>,
}

/// Lint result for a tree of files.
#[derive(Debug, Clone, Default)]
pub struct TreeReport {
    /// All findings, sorted by (path, line).
    pub findings: Vec<Finding>,
    /// All waivers, in path order.
    pub waivers: Vec<Waiver>,
    /// Number of files scanned.
    pub files: usize,
    /// Call-graph resolution statistics from the chain pass.
    pub stats: GraphStats,
}

/// Index one past the matching `]` of an attribute starting at `i`
/// (`#` `[` …), or `None` if `i` does not start an attribute.
fn attr_end(code: &[Token], i: usize) -> Option<usize> {
    let at = |k: usize, s: &str| code.get(k).is_some_and(|t| t.kind == TokKind::Punct && t.text == s);
    if !(at(i, "#") && at(i + 1, "[")) {
        return None;
    }
    let mut depth = 1i32;
    let mut j = i + 2;
    while j < code.len() {
        if code[j].kind == TokKind::Punct {
            match code[j].text.as_str() {
                "[" => depth += 1,
                "]" => {
                    depth -= 1;
                    if depth == 0 {
                        return Some(j + 1);
                    }
                }
                _ => {}
            }
        }
        j += 1;
    }
    None
}

/// True if the attribute spanning `i..end` is `#[test]` or `#[cfg(test)]`.
fn is_test_attr(code: &[Token], i: usize, end: usize) -> bool {
    let c: Vec<&str> = code[i + 2..end - 1].iter().map(|t| t.text.as_str()).collect();
    c == ["test"] || c == ["cfg", "(", "test", ")"]
}

/// Index of the `}` matching the `{` at `open` (last token if unbalanced).
fn match_brace(code: &[Token], open: usize) -> usize {
    let mut depth = 0i32;
    let mut j = open;
    while j < code.len() {
        if code[j].kind == TokKind::Punct {
            match code[j].text.as_str() {
                "{" => depth += 1,
                "}" => {
                    depth -= 1;
                    if depth == 0 {
                        return j;
                    }
                }
                _ => {}
            }
        }
        j += 1;
    }
    code.len().saturating_sub(1)
}

/// Inclusive line ranges covered by `#[cfg(test)]` / `#[test]` items:
/// from the attribute to the item's closing brace (or terminating `;`).
fn test_exempt_ranges(code: &[Token]) -> Vec<(u32, u32)> {
    let mut ranges = Vec::new();
    let mut i = 0usize;
    while i < code.len() {
        let Some(end) = attr_end(code, i) else {
            i += 1;
            continue;
        };
        let start_line = code[i].line;
        let mut is_test = is_test_attr(code, i, end);
        // absorb the whole attribute run; any test attr marks the item
        let mut k = end;
        while let Some(e2) = attr_end(code, k) {
            is_test = is_test || is_test_attr(code, k, e2);
            k = e2;
        }
        if !is_test {
            i = k;
            continue;
        }
        // the item body: first `{` outside parens/brackets, or a bare `;`
        let mut depth = 0i32;
        let mut found = false;
        while k < code.len() {
            if code[k].kind == TokKind::Punct {
                match code[k].text.as_str() {
                    "(" | "[" => depth += 1,
                    ")" | "]" => depth -= 1,
                    "{" if depth == 0 => {
                        let close = match_brace(code, k);
                        ranges.push((start_line, code[close].line));
                        k = close + 1;
                        found = true;
                    }
                    ";" if depth == 0 => {
                        ranges.push((start_line, code[k].line));
                        k += 1;
                        found = true;
                    }
                    _ => {}
                }
            }
            if found {
                break;
            }
            k += 1;
        }
        if !found {
            let last = code.last().map(|t| t.line).unwrap_or(start_line);
            ranges.push((start_line, last));
        }
        i = k;
    }
    ranges
}

fn in_exempt(ranges: &[(u32, u32)], line: u32) -> bool {
    ranges.iter().any(|&(a, b)| a <= line && line <= b)
}

/// Parsed waiver or a syntax error message for a malformed one.
enum WaiverParse {
    NotAWaiver,
    Ok(Vec<String>, String),
    Bad(String),
}

/// Parse a `lint:allow` waiver out of one line comment. Doc comments
/// (`///`, `//!`) never carry waivers.
fn parse_waiver(comment: &str) -> WaiverParse {
    let body = match comment.strip_prefix("//") {
        Some(b) => b,
        None => return WaiverParse::NotAWaiver,
    };
    if body.starts_with('/') || body.starts_with('!') {
        return WaiverParse::NotAWaiver;
    }
    let body = body.trim_start();
    let Some(rest) = body.strip_prefix("lint:allow") else {
        return WaiverParse::NotAWaiver;
    };
    let rest = rest.trim_start();
    let Some(rest) = rest.strip_prefix('(') else {
        return WaiverParse::Bad("waiver must name its rules: lint:allow(<rule>)".to_string());
    };
    let Some(close) = rest.find(')') else {
        return WaiverParse::Bad("unclosed rule list in lint:allow(".to_string());
    };
    let mut names = Vec::new();
    for raw in rest[..close].split(',') {
        let name = raw.trim();
        if name.is_empty() {
            return WaiverParse::Bad("empty rule name in lint:allow(...)".to_string());
        }
        if !WAIVABLE_RULES.contains(&name) {
            return WaiverParse::Bad(format!(
                "unknown or unwaivable rule `{name}` (waivable: {})",
                WAIVABLE_RULES.join(", ")
            ));
        }
        names.push(name.to_string());
    }
    let after = rest[close + 1..].trim_start();
    let Some(just) = after.strip_prefix("--") else {
        return WaiverParse::Bad(
            "waiver without justification; write: lint:allow(<rule>) -- <why this is sound>"
                .to_string(),
        );
    };
    let just = just.trim();
    if just.is_empty() {
        return WaiverParse::Bad(
            "waiver without justification; write: lint:allow(<rule>) -- <why this is sound>"
                .to_string(),
        );
    }
    WaiverParse::Ok(names, just.to_string())
}

/// Lint one file's source. `path` is the repo-relative path used both for
/// classification and reporting, so fixtures can be linted *as if* they
/// lived in a contract module.
pub fn lint_source(path: &str, src: &str) -> FileReport {
    let class = classify(path);
    let toks = tokenize(src);
    let mut findings: Vec<Finding> = Vec::new();
    let mut waivers: Vec<Waiver> = Vec::new();
    let mut code: Vec<Token> = Vec::with_capacity(toks.len());
    for t in toks {
        match t.kind {
            TokKind::LineComment => match parse_waiver(&t.text) {
                WaiverParse::NotAWaiver => {}
                WaiverParse::Ok(rules, justification) => waivers.push(Waiver {
                    path: path.to_string(),
                    line: t.line,
                    rules,
                    justification,
                    used: false,
                }),
                WaiverParse::Bad(msg) => findings.push(Finding {
                    path: path.to_string(),
                    line: t.line,
                    rule: RULE_WAIVER_SYNTAX,
                    message: msg,
                    chain: Vec::new(),
                }),
            },
            TokKind::BlockComment => {}
            _ => code.push(t),
        }
    }
    let exempt = test_exempt_ranges(&code);

    let mut raw: Vec<RawFinding> = Vec::new();
    if !class.test_only {
        if class.determinism {
            check_clock(&code, &mut raw);
            check_unordered(&code, &mut raw);
        }
        if class.rng_scope {
            check_rng(&code, &mut raw);
        }
        if class.panic_sensitive {
            check_panic(&code, &mut raw);
        }
        check_debug_assert(&code, &mut raw);
    }
    raw.retain(|f| !in_exempt(&exempt, f.line));

    for f in raw {
        let mut waived = false;
        for w in waivers.iter_mut() {
            let covers = w.line == f.line || w.line + 1 == f.line;
            if covers && w.rules.iter().any(|r| r == f.rule) {
                w.used = true;
                waived = true;
            }
        }
        if !waived {
            findings.push(Finding {
                path: path.to_string(),
                line: f.line,
                rule: f.rule,
                message: f.message,
                chain: Vec::new(),
            });
        }
    }
    for w in &waivers {
        if !w.used && !class.test_only && !in_exempt(&exempt, w.line) {
            findings.push(Finding {
                path: path.to_string(),
                line: w.line,
                rule: RULE_UNUSED_WAIVER,
                message: format!(
                    "waiver for `{}` suppresses nothing; delete it or move it next to \
                     the finding it covers",
                    w.rules.join(", ")
                ),
                chain: Vec::new(),
            });
        }
    }
    findings.sort_by(|a, b| a.line.cmp(&b.line).then(a.rule.cmp(b.rule)));
    FileReport { findings, waivers }
}

/// The chain-checked rule families, in hit-table order: each pairs a
/// per-file token check with the [`FileClass`] flag that marks a file's
/// fns as contract entry points for that family.
const FAMILIES: [&str; 4] = [RULE_CLOCK, RULE_UNORDERED, RULE_RNG, RULE_PANIC];

fn family_check(fam: &str, code: &[Token], out: &mut Vec<RawFinding>) {
    if fam == RULE_CLOCK {
        check_clock(code, out);
    } else if fam == RULE_UNORDERED {
        check_unordered(code, out);
    } else if fam == RULE_RNG {
        check_rng(code, out);
    } else if fam == RULE_PANIC {
        check_panic(code, out);
    }
}

fn family_class(fam: &str, c: &FileClass) -> bool {
    if fam == RULE_CLOCK || fam == RULE_UNORDERED {
        c.determinism
    } else if fam == RULE_RNG {
        c.rng_scope
    } else {
        c.panic_sensitive
    }
}

/// Everything the crate-wide pass needs from one file: classification,
/// code tokens, waivers, exempt ranges, and the per-family rule hits
/// (computed once, unconditionally — the direct pass consumes the
/// families the file's class enables, the chain pass the rest).
struct FileAnalysis {
    path: String,
    class: FileClass,
    code: Vec<Token>,
    waivers: Vec<Waiver>,
    early_findings: Vec<Finding>,
    exempt: Vec<(u32, u32)>,
    /// Per-family hits, indexed like [`FAMILIES`], test-exempt filtered.
    hits: Vec<Vec<RawFinding>>,
    debug_assert_hits: Vec<RawFinding>,
    module: Option<Vec<String>>,
}

fn analyze_file(path: &str, src: &str) -> FileAnalysis {
    let class = classify(path);
    let toks = tokenize(src);
    let mut code: Vec<Token> = Vec::with_capacity(toks.len());
    let mut waivers: Vec<Waiver> = Vec::new();
    let mut early_findings: Vec<Finding> = Vec::new();
    for t in toks {
        match t.kind {
            TokKind::LineComment => match parse_waiver(&t.text) {
                WaiverParse::NotAWaiver => {}
                WaiverParse::Ok(rules, justification) => waivers.push(Waiver {
                    path: path.to_string(),
                    line: t.line,
                    rules,
                    justification,
                    used: false,
                }),
                WaiverParse::Bad(msg) => early_findings.push(Finding {
                    path: path.to_string(),
                    line: t.line,
                    rule: RULE_WAIVER_SYNTAX,
                    message: msg,
                    chain: Vec::new(),
                }),
            },
            TokKind::BlockComment => {}
            _ => code.push(t),
        }
    }
    let exempt = test_exempt_ranges(&code);
    let mut hits: Vec<Vec<RawFinding>> = Vec::with_capacity(FAMILIES.len());
    for fam in FAMILIES {
        let mut out = Vec::new();
        family_check(fam, &code, &mut out);
        out.retain(|h| !in_exempt(&exempt, h.line));
        hits.push(out);
    }
    let mut debug_assert_hits = Vec::new();
    check_debug_assert(&code, &mut debug_assert_hits);
    debug_assert_hits.retain(|h| !in_exempt(&exempt, h.line));
    FileAnalysis {
        path: path.to_string(),
        class,
        code,
        waivers,
        early_findings,
        exempt,
        hits,
        debug_assert_hits,
        module: module_path_of(path),
    }
}

/// Mark every waiver covering (`rule`, `line`) used; true if any did.
/// A waiver on line L covers hits on L and L+1, same as v1.
fn waive(waivers: &mut [Waiver], rule: &str, line: u32) -> bool {
    let mut waived = false;
    for w in waivers.iter_mut() {
        let covers = w.line == line || w.line + 1 == line;
        if covers && w.rules.iter().any(|r| r == rule) {
            w.used = true;
            waived = true;
        }
    }
    waived
}

/// Lint a set of files *as one crate*: the v1 per-file direct pass, the
/// classification completeness meta-rule, and the v2 call-graph chain
/// pass (rule hits in fns reachable from contract entry points, reported
/// with the full call chain and anchored at the source site). `files`
/// are `(repo-relative path, source)` pairs, so fixtures can be linted
/// under virtual paths.
pub fn lint_files(files: &[(String, String)]) -> TreeReport {
    let mut analyses: Vec<FileAnalysis> =
        files.iter().map(|(p, s)| analyze_file(p, s)).collect();
    let mut findings: Vec<Finding> = Vec::new();
    // ---- per-file direct pass (identical to v1 lint_source) ----
    let mut direct_sites: std::collections::BTreeSet<(String, u32, &'static str)> =
        std::collections::BTreeSet::new();
    for fa in analyses.iter_mut() {
        findings.append(&mut fa.early_findings);
        if fa.class.test_only {
            continue;
        }
        let mut raw: Vec<RawFinding> = Vec::new();
        if fa.class.determinism {
            raw.extend(fa.hits[0].iter().cloned()); // clock
            raw.extend(fa.hits[1].iter().cloned()); // unordered
        }
        if fa.class.rng_scope {
            raw.extend(fa.hits[2].iter().cloned());
        }
        if fa.class.panic_sensitive {
            raw.extend(fa.hits[3].iter().cloned());
        }
        raw.extend(fa.debug_assert_hits.iter().cloned());
        for h in raw {
            if waive(&mut fa.waivers, h.rule, h.line) {
                continue;
            }
            direct_sites.insert((fa.path.clone(), h.line, h.rule));
            findings.push(Finding {
                path: fa.path.clone(),
                line: h.line,
                rule: h.rule,
                message: h.message,
                chain: Vec::new(),
            });
        }
    }
    // ---- classification completeness meta-rule ----
    for fa in &analyses {
        let p = fa.path.replace('\\', "/");
        if fa.class.test_only || p.contains("lint/fixtures") {
            continue;
        }
        if (p.contains("src/solver/") || p.contains("src/sim/"))
            && !DETERMINISM_FILES
                .iter()
                .chain(KNOWN_NON_CONTRACT.iter())
                .any(|s| p.ends_with(s))
        {
            findings.push(Finding {
                path: fa.path.clone(),
                line: 1,
                rule: RULE_UNCLASSIFIED,
                message: "new module under src/solver/ or src/sim/ is not explicitly \
                          classified; add it to DETERMINISM_FILES or KNOWN_NON_CONTRACT \
                          in rust/src/lint/mod.rs (and LINTS.md)"
                    .to_string(),
                chain: Vec::new(),
            });
        }
    }
    // ---- call graph + chain pass ----
    let graph_idx: Vec<usize> = analyses
        .iter()
        .enumerate()
        .filter(|(_, fa)| fa.module.is_some() && !fa.class.test_only)
        .map(|(i, _)| i)
        .collect();
    let units: Vec<FileUnit> = graph_idx
        .iter()
        .map(|&ai| {
            let fa = &analyses[ai];
            let (items, uses, globs) = parse_items(&fa.code);
            FileUnit {
                path: fa.path.clone(),
                module: fa.module.clone().unwrap_or_default(),
                code: fa.code.clone(),
                items,
                uses,
                globs,
                exempt: fa.exempt.clone(),
            }
        })
        .collect();
    let g = build_graph(&units);
    for (fi, fam) in FAMILIES.iter().enumerate() {
        // multi-source BFS from every non-exempt fn of this family's
        // contract-classified files, recording parents for chain labels
        let mut parent: std::collections::BTreeMap<usize, Option<usize>> =
            std::collections::BTreeMap::new();
        let mut queue: Vec<usize> = Vec::new();
        for (fid, f) in g.fns.iter().enumerate() {
            if !f.exempt && family_class(fam, &analyses[graph_idx[f.unit]].class) {
                parent.insert(fid, None);
                queue.push(fid);
            }
        }
        let mut qi = 0usize;
        while qi < queue.len() {
            let cur = queue[qi];
            qi += 1;
            if let Some(nbrs) = g.edges.get(&cur) {
                for &nxt in nbrs {
                    if let std::collections::btree_map::Entry::Vacant(e) = parent.entry(nxt) {
                        e.insert(Some(cur));
                        queue.push(nxt);
                    }
                }
            }
        }
        // hits inside reachable fns of NON-classified files become chain
        // findings, anchored at the source site (the fix location)
        let mut seen_sites: std::collections::BTreeSet<(String, u32, &'static str)> =
            std::collections::BTreeSet::new();
        for &fid in &queue {
            let (unit_idx, lo, hi) = {
                let f = &g.fns[fid];
                (f.unit, f.lines.0, f.lines.1)
            };
            let ai = graph_idx[unit_idx];
            if family_class(fam, &analyses[ai].class) {
                continue; // direct pass owns hits in contract-classified files
            }
            let hits: Vec<RawFinding> = analyses[ai].hits[fi].clone();
            for h in hits {
                if h.line < lo || h.line > hi {
                    continue;
                }
                // innermost-fn attribution: a hit belongs to the
                // narrowest fn spanning its line
                if innermost_fn_at(&g, unit_idx, h.line).is_some_and(|inner| inner != fid) {
                    continue;
                }
                let site = (analyses[ai].path.clone(), h.line, h.rule);
                if seen_sites.contains(&site) || direct_sites.contains(&site) {
                    continue;
                }
                seen_sites.insert(site);
                if waive(&mut analyses[ai].waivers, h.rule, h.line) {
                    continue;
                }
                let mut chain_ids = vec![fid];
                let mut cur = fid;
                while let Some(Some(p)) = parent.get(&cur) {
                    cur = *p;
                    chain_ids.push(cur);
                }
                chain_ids.reverse();
                let mut chain: Vec<String> = chain_ids
                    .iter()
                    .map(|&c| format!("{}::{}", units[g.fns[c].unit].path, g.fns[c].name))
                    .collect();
                chain.push(h.what.clone());
                findings.push(Finding {
                    path: analyses[ai].path.clone(),
                    line: h.line,
                    rule: h.rule,
                    message: format!(
                        "reachable from a contract entry point: {}; {}",
                        chain.join(" → "),
                        h.message
                    ),
                    chain,
                });
            }
        }
    }
    // ---- unused waivers (crate-wide: chain suppression counts as use) ----
    for fa in &analyses {
        if fa.class.test_only {
            continue;
        }
        for w in &fa.waivers {
            if !w.used && !in_exempt(&fa.exempt, w.line) {
                findings.push(Finding {
                    path: fa.path.clone(),
                    line: w.line,
                    rule: RULE_UNUSED_WAIVER,
                    message: format!(
                        "waiver for `{}` suppresses nothing; delete it or move it next to \
                         the finding it covers",
                        w.rules.join(", ")
                    ),
                    chain: Vec::new(),
                });
            }
        }
    }
    findings.sort_by_key(|f| (f.path.clone(), f.line, f.rule));
    let waivers: Vec<Waiver> = analyses.into_iter().flat_map(|fa| fa.waivers).collect();
    TreeReport { findings, waivers, files: files.len(), stats: g.stats }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out
}

fn json_str_list(items: &[String]) -> String {
    let quoted: Vec<String> = items.iter().map(|s| format!("\"{}\"", json_escape(s))).collect();
    format!("[{}]", quoted.join(", "))
}

impl TreeReport {
    /// Serialize the report (findings with chains, the waiver inventory,
    /// and the call-graph stats) as JSON — hand-rolled, dependency-free,
    /// deterministic key order. CI uploads this as a build artifact.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n  \"findings\": [");
        for (i, f) in self.findings.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "\n    {{\"path\": \"{}\", \"line\": {}, \"rule\": \"{}\", \
                 \"message\": \"{}\", \"chain\": {}}}",
                json_escape(&f.path),
                f.line,
                json_escape(f.rule),
                json_escape(&f.message),
                json_str_list(&f.chain),
            ));
        }
        if !self.findings.is_empty() {
            out.push_str("\n  ");
        }
        out.push_str("],\n  \"waivers\": [");
        for (i, w) in self.waivers.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "\n    {{\"path\": \"{}\", \"line\": {}, \"rules\": {}, \
                 \"justification\": \"{}\", \"used\": {}}}",
                json_escape(&w.path),
                w.line,
                json_str_list(&w.rules),
                json_escape(&w.justification),
                w.used,
            ));
        }
        if !self.waivers.is_empty() {
            out.push_str("\n  ");
        }
        let s = &self.stats;
        out.push_str(&format!(
            "],\n  \"files\": {},\n  \"stats\": {{\"functions\": {}, \"call_sites\": {}, \
             \"resolved_calls\": {}, \"resolved_edges\": {}, \"external_calls\": {}, \
             \"ctor_calls\": {}, \"local_calls\": {}, \"unresolved_calls\": {}, \
             \"ambiguous_methods\": {}, \"unresolved_rate\": {:.6}}}\n}}\n",
            self.files,
            s.functions,
            s.call_sites,
            s.resolved_calls,
            s.resolved_edges,
            s.external_calls,
            s.ctor_calls,
            s.local_calls,
            s.unresolved_calls,
            s.ambiguous_methods,
            s.unresolved_rate(),
        ));
        out
    }
}

/// Recursively collect `.rs` files (deterministic order: sorted by name).
fn collect_rs_files(path: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    if path.is_dir() {
        let mut entries: Vec<PathBuf> = std::fs::read_dir(path)?
            .map(|e| e.map(|d| d.path()))
            .collect::<std::io::Result<Vec<PathBuf>>>()?;
        entries.sort();
        for e in entries {
            collect_rs_files(&e, out)?;
        }
    } else if path.extension().is_some_and(|e| e == "rs") {
        out.push(path.to_path_buf());
    }
    Ok(())
}

/// Lint every `.rs` file under `root`-relative paths `rels`. The lint's
/// own rule fixtures (`lint/fixtures/`) are skipped — they deliberately
/// violate every rule and are exercised by the fixture tests instead.
pub fn lint_tree(root: &Path, rels: &[&str]) -> std::io::Result<TreeReport> {
    let mut files: Vec<PathBuf> = Vec::new();
    for rel in rels {
        let p = root.join(rel);
        if !p.exists() {
            return Err(std::io::Error::new(
                std::io::ErrorKind::NotFound,
                format!("no such path: {}", p.display()),
            ));
        }
        collect_rs_files(&p, &mut files)?;
    }
    files.sort();
    files.dedup();
    let mut inputs: Vec<(String, String)> = Vec::new();
    for f in &files {
        let disp = f
            .strip_prefix(root)
            .unwrap_or(f.as_path())
            .to_string_lossy()
            .replace('\\', "/");
        if disp.contains("lint/fixtures") {
            continue;
        }
        inputs.push((disp, std::fs::read_to_string(f)?));
    }
    Ok(lint_files(&inputs))
}

#[cfg(test)]
mod tests {
    use super::rules::{RULE_CLOCK, RULE_DEBUG_ASSERT, RULE_PANIC, RULE_RNG, RULE_UNORDERED};
    use super::*;

    fn rules_fired(report: &FileReport) -> Vec<&'static str> {
        report.findings.iter().map(|f| f.rule).collect()
    }

    // ---- classification --------------------------------------------------

    #[test]
    fn classification_matches_contract_map() {
        let c = classify("rust/src/solver/delta.rs");
        assert!(c.determinism && c.rng_scope && !c.panic_sensitive && !c.test_only);
        let c = classify("rust/src/sim/mod.rs");
        assert!(c.determinism && c.rng_scope && !c.panic_sensitive);
        let c = classify("rust/src/sim/chaos.rs");
        assert!(
            c.determinism && c.rng_scope && c.panic_sensitive,
            "the chaos state machine carries every contract: deterministic AND panic-free"
        );
        let c = classify("rust/src/solver/milp.rs");
        assert!(!c.determinism && c.rng_scope, "milp is rng-scoped but not a contract file");
        let c = classify("rust/src/solver/risk.rs");
        assert!(
            c.determinism && c.rng_scope && !c.panic_sensitive,
            "risk pricing runs inside every evaluator: deterministic, DetRng-only"
        );
        let c = classify("rust/src/sim/events.rs");
        assert!(
            c.determinism && c.rng_scope && !c.panic_sensitive,
            "the event queue orders every sim run: explicitly determinism-contract"
        );
        assert!(
            DETERMINISM_FILES.contains(&"src/sim/events.rs"),
            "events.rs must be explicitly classified, not just swept in by src/sim/"
        );
        let c = classify("rust/src/online/mod.rs");
        assert!(c.panic_sensitive && !c.determinism);
        let c = classify("rust/src/coordinator/mod.rs");
        assert!(c.panic_sensitive);
        let c = classify("rust/tests/prop_invariants.rs");
        assert!(c.test_only);
        let c = classify("rust/benches/bench_solver.rs");
        assert!(c.test_only);
        let c = classify("examples/quickstart.rs");
        assert!(!c.determinism && !c.rng_scope && !c.panic_sensitive && !c.test_only);
        let c = classify("rust/src/util/mod.rs");
        assert!(!c.determinism && !c.rng_scope, "util::Deadline is the sanctioned clock site");
    }

    // ---- test-region exemption -------------------------------------------

    #[test]
    fn cfg_test_modules_are_exempt() {
        let src = "fn live() {}\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                       #[test]\n\
                       fn t() { x.unwrap(); let i = std::time::Instant::now(); }\n\
                   }\n";
        let r = lint_source("rust/src/online/mod.rs", src);
        assert!(r.findings.is_empty(), "{:?}", r.findings);
        let r = lint_source("rust/src/solver/anneal.rs", src);
        assert!(r.findings.is_empty(), "{:?}", r.findings);
    }

    #[test]
    fn test_attribute_on_a_single_fn_is_exempt_but_neighbors_are_not() {
        let src = "#[test]\n\
                   fn t() { x.unwrap(); }\n\
                   fn live() { y.unwrap(); }\n";
        let r = lint_source("rust/src/online/mod.rs", src);
        assert_eq!(rules_fired(&r), [RULE_PANIC]);
        assert_eq!(r.findings[0].line, 3);
    }

    #[test]
    fn cfg_not_test_is_not_exempt() {
        let src = "#[cfg(not(test))]\nfn live() { x.unwrap(); }\n";
        let r = lint_source("rust/src/online/mod.rs", src);
        assert_eq!(rules_fired(&r), [RULE_PANIC]);
    }

    // ---- waivers ----------------------------------------------------------

    #[test]
    fn waiver_on_previous_line_suppresses_and_is_inventoried() {
        let src = "// lint:allow(panic-freedom) -- startup-only invariant, documented\n\
                   fn live() { x.unwrap(); }\n";
        let r = lint_source("rust/src/online/mod.rs", src);
        assert!(r.findings.is_empty(), "{:?}", r.findings);
        assert_eq!(r.waivers.len(), 1);
        assert_eq!(r.waivers[0].rules, ["panic-freedom"]);
        assert!(r.waivers[0].justification.contains("startup-only"));
    }

    #[test]
    fn trailing_waiver_on_the_same_line_suppresses() {
        let src = "fn live() { x.unwrap(); } // lint:allow(panic-freedom) -- demo harness\n";
        let r = lint_source("rust/src/online/mod.rs", src);
        assert!(r.findings.is_empty(), "{:?}", r.findings);
    }

    #[test]
    fn waiver_without_justification_is_an_error() {
        let src = "// lint:allow(panic-freedom)\nfn live() { x.unwrap(); }\n";
        let r = lint_source("rust/src/online/mod.rs", src);
        // the malformed waiver does not suppress, so both errors surface
        let fired = rules_fired(&r);
        assert!(fired.contains(&rules::RULE_WAIVER_SYNTAX), "{fired:?}");
        assert!(fired.contains(&RULE_PANIC), "{fired:?}");
    }

    #[test]
    fn waiver_for_unknown_rule_is_an_error() {
        let src = "// lint:allow(made-up-rule) -- because\nfn live() {}\n";
        let r = lint_source("rust/src/online/mod.rs", src);
        assert_eq!(rules_fired(&r), [rules::RULE_WAIVER_SYNTAX]);
        assert!(r.findings[0].message.contains("made-up-rule"));
    }

    #[test]
    fn unused_waiver_is_an_error() {
        let src = "// lint:allow(panic-freedom) -- nothing here panics anymore\nfn live() {}\n";
        let r = lint_source("rust/src/online/mod.rs", src);
        assert_eq!(rules_fired(&r), [RULE_UNUSED_WAIVER]);
    }

    #[test]
    fn doc_comments_never_waive() {
        let src = "/// lint:allow(panic-freedom) -- docs cannot waive\nfn live() { x.unwrap(); }\n";
        let r = lint_source("rust/src/online/mod.rs", src);
        assert_eq!(rules_fired(&r), [RULE_PANIC]);
        assert!(r.waivers.is_empty());
    }

    #[test]
    fn one_waiver_covers_a_multi_rule_list() {
        let src = "// lint:allow(clock-in-evaluator, ambient-rng) -- calibration-only path\n\
                   fn f() { let t = Instant::now(); let h = RandomState::new(); }\n";
        let r = lint_source("rust/src/solver/delta.rs", src);
        assert!(r.findings.is_empty(), "{:?}", r.findings);
        assert_eq!(r.waivers[0].rules.len(), 2);
    }

    // ---- fixtures: each rule fires on its bad twin, not its good twin ----

    #[test]
    fn fixture_clock_in_evaluator() {
        let bad = lint_source("rust/src/solver/anneal.rs", include_str!("fixtures/clock_bad.rs"));
        assert!(!bad.findings.is_empty());
        assert!(bad.findings.iter().all(|f| f.rule == RULE_CLOCK), "{:?}", bad.findings);
        let good = lint_source("rust/src/solver/anneal.rs", include_str!("fixtures/clock_good.rs"));
        assert!(good.findings.is_empty(), "{:?}", good.findings);
    }

    #[test]
    fn fixture_unordered_iteration() {
        let bad = lint_source("rust/src/sim/mod.rs", include_str!("fixtures/unordered_bad.rs"));
        assert!(bad.findings.len() >= 3, "{:?}", bad.findings);
        assert!(bad.findings.iter().all(|f| f.rule == RULE_UNORDERED), "{:?}", bad.findings);
        let good = lint_source("rust/src/sim/mod.rs", include_str!("fixtures/unordered_good.rs"));
        assert!(good.findings.is_empty(), "{:?}", good.findings);
    }

    #[test]
    fn fixture_ambient_rng() {
        let bad = lint_source("rust/src/solver/spase.rs", include_str!("fixtures/rng_bad.rs"));
        assert!(!bad.findings.is_empty());
        assert!(bad.findings.iter().all(|f| f.rule == RULE_RNG), "{:?}", bad.findings);
        let good = lint_source("rust/src/solver/spase.rs", include_str!("fixtures/rng_good.rs"));
        assert!(good.findings.is_empty(), "{:?}", good.findings);
    }

    #[test]
    fn fixture_panic_freedom() {
        let bad = lint_source("rust/src/online/mod.rs", include_str!("fixtures/panic_bad.rs"));
        assert!(bad.findings.len() >= 5, "{:?}", bad.findings);
        assert!(bad.findings.iter().all(|f| f.rule == RULE_PANIC), "{:?}", bad.findings);
        let good = lint_source("rust/src/online/mod.rs", include_str!("fixtures/panic_good.rs"));
        assert!(good.findings.is_empty(), "{:?}", good.findings);
    }

    #[test]
    fn fixture_chaos_panic() {
        let bad = lint_source("rust/src/sim/chaos.rs", include_str!("fixtures/chaos_panic_bad.rs"));
        assert!(bad.findings.len() >= 4, "{:?}", bad.findings);
        assert!(bad.findings.iter().all(|f| f.rule == RULE_PANIC), "{:?}", bad.findings);
        let good =
            lint_source("rust/src/sim/chaos.rs", include_str!("fixtures/chaos_panic_good.rs"));
        assert!(good.findings.is_empty(), "{:?}", good.findings);
    }

    #[test]
    fn fixture_risk_determinism() {
        let bad = lint_source("rust/src/solver/risk.rs", include_str!("fixtures/risk_bad.rs"));
        let fired = rules_fired(&bad);
        assert!(fired.contains(&RULE_CLOCK), "{fired:?}");
        assert!(fired.contains(&RULE_UNORDERED), "{fired:?}");
        assert!(fired.contains(&RULE_RNG), "{fired:?}");
        let good = lint_source("rust/src/solver/risk.rs", include_str!("fixtures/risk_good.rs"));
        assert!(good.findings.is_empty(), "{:?}", good.findings);
    }

    #[test]
    fn fixture_debug_assert_side_effect() {
        let bad =
            lint_source("rust/src/solver/anneal.rs", include_str!("fixtures/debug_assert_bad.rs"));
        assert!(!bad.findings.is_empty());
        assert!(bad.findings.iter().all(|f| f.rule == RULE_DEBUG_ASSERT), "{:?}", bad.findings);
        let good =
            lint_source("rust/src/solver/anneal.rs", include_str!("fixtures/debug_assert_good.rs"));
        assert!(good.findings.is_empty(), "{:?}", good.findings);
    }

    #[test]
    fn fixture_waivers() {
        let bad = lint_source("rust/src/online/mod.rs", include_str!("fixtures/waiver_bad.rs"));
        let fired = rules_fired(&bad);
        assert!(fired.contains(&rules::RULE_WAIVER_SYNTAX), "{fired:?}");
        assert!(fired.contains(&RULE_UNUSED_WAIVER), "{fired:?}");
        let good = lint_source("rust/src/online/mod.rs", include_str!("fixtures/waiver_good.rs"));
        assert!(good.findings.is_empty(), "{:?}", good.findings);
        assert!(!good.waivers.is_empty());
    }

    // ---- the real tree ----------------------------------------------------

    #[test]
    fn real_tree_is_lint_clean() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let report = match lint_tree(root, &DEFAULT_ROOTS) {
            Ok(r) => r,
            Err(e) => panic!("tree walk failed: {e}"),
        };
        assert!(report.files > 50, "walker found suspiciously few files: {}", report.files);
        let msgs: Vec<String> = report.findings.iter().map(|f| f.to_string()).collect();
        assert!(report.findings.is_empty(), "the tree must be lint-clean:\n{}", msgs.join("\n"));
        assert!(
            report.waivers.len() >= 5,
            "the joint.rs/util/anneal waivers should be inventoried: {:?}",
            report.waivers
        );
        assert!(
            report.waivers.iter().all(|w| w.used),
            "every waiver in the tree must be in force: {:?}",
            report.waivers.iter().filter(|w| !w.used).collect::<Vec<_>>()
        );
        assert!(
            report.stats.unresolved_rate() <= 0.002,
            "call resolution regressed past the pinned baseline: {:?}",
            report.stats
        );
        assert!(report.stats.functions > 300, "graph too small: {:?}", report.stats);
    }

    /// Acceptance demo: deleting any one waiver comment makes the lint
    /// exit non-zero — here, the `joint.rs` deadline-read waivers.
    #[test]
    fn deleting_a_waiver_surfaces_the_underlying_finding() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let path = "rust/src/solver/joint.rs";
        let src = match std::fs::read_to_string(root.join(path)) {
            Ok(s) => s,
            Err(e) => panic!("reading {path}: {e}"),
        };
        let with = lint_source(path, &src);
        assert!(with.findings.is_empty(), "{:?}", with.findings);
        let clock_waivers =
            with.waivers.iter().filter(|w| w.rules.iter().any(|r| r == RULE_CLOCK)).count();
        assert!(clock_waivers >= 2, "expected the two deadline-read waivers, saw {clock_waivers}");
        let stripped: String = src
            .lines()
            .filter(|l| !l.contains("lint:allow"))
            .map(|l| format!("{l}\n"))
            .collect();
        let without = lint_source(path, &stripped);
        let clocks = without.findings.iter().filter(|f| f.rule == RULE_CLOCK).count();
        assert!(clocks >= 2, "stripping waivers must surface the clock reads: {:?}", without.findings);
    }

    // ---- v2: cross-file call chains ---------------------------------------

    /// The xchain fixture twins under their virtual crate paths: a clean
    /// determinism entry (`delta.rs`), a clean panic entry (`online`),
    /// a clean mid hop (`metrics`), and one of three helper twins
    /// (`util/buf.rs`) carrying the actual bodies.
    fn xchain_files(helper: &str) -> Vec<(String, String)> {
        vec![
            (
                "rust/src/solver/delta.rs".to_string(),
                include_str!("fixtures/xchain_entry.rs").to_string(),
            ),
            (
                "rust/src/metrics/mod.rs".to_string(),
                include_str!("fixtures/xchain_mid.rs").to_string(),
            ),
            (
                "rust/src/online/mod.rs".to_string(),
                include_str!("fixtures/xchain_panic_entry.rs").to_string(),
            ),
            ("rust/src/util/buf.rs".to_string(), helper.to_string()),
        ]
    }

    #[test]
    fn xchain_bad_twin_reports_one_chain_finding_per_family() {
        let r = lint_files(&xchain_files(include_str!("fixtures/xchain_helper_bad.rs")));
        let got: Vec<(&str, &'static str, u32)> =
            r.findings.iter().map(|f| (f.path.as_str(), f.rule, f.line)).collect();
        assert_eq!(
            got,
            [
                ("rust/src/util/buf.rs", RULE_CLOCK, 9),
                ("rust/src/util/buf.rs", RULE_UNORDERED, 14),
                ("rust/src/util/buf.rs", RULE_RNG, 18),
                ("rust/src/util/buf.rs", RULE_PANIC, 23),
            ],
            "chain findings must anchor at the source site: {:?}",
            r.findings
        );
        let clock = &r.findings[0];
        assert_eq!(
            clock.chain,
            [
                "rust/src/solver/delta.rs::eval_move",
                "rust/src/metrics/mod.rs::window_stats",
                "rust/src/util/buf.rs::now_secs",
                "`Instant::now`",
            ],
            "the clock chain must run entry → metrics → util → token"
        );
        assert!(
            clock.message.starts_with("reachable from a contract entry point: "),
            "{}",
            clock.message
        );
        let panic = &r.findings[3];
        assert_eq!(
            panic.chain.first().map(String::as_str),
            Some("rust/src/online/mod.rs::ingest"),
            "the panic chain starts at the online entry point: {:?}",
            panic.chain
        );
    }

    #[test]
    fn xchain_good_twin_is_silent() {
        let r = lint_files(&xchain_files(include_str!("fixtures/xchain_helper_good.rs")));
        assert!(r.findings.is_empty(), "{:?}", r.findings);
    }

    #[test]
    fn xchain_waived_twin_is_silent_with_all_source_waivers_used() {
        let r = lint_files(&xchain_files(include_str!("fixtures/xchain_helper_waived.rs")));
        assert!(r.findings.is_empty(), "{:?}", r.findings);
        let used = r
            .waivers
            .iter()
            .filter(|w| w.used && w.path == "rust/src/util/buf.rs")
            .count();
        assert_eq!(used, 4, "a source-site waiver must suppress the chains through it");
    }

    #[test]
    fn deleting_the_xchain_clock_waiver_surfaces_exactly_that_chain() {
        let helper: String = include_str!("fixtures/xchain_helper_waived.rs")
            .lines()
            .filter(|l| !l.contains("clock-in-evaluator"))
            .map(|l| format!("{l}\n"))
            .collect();
        let r = lint_files(&xchain_files(&helper));
        let fired: Vec<&'static str> = r.findings.iter().map(|f| f.rule).collect();
        assert_eq!(fired, [RULE_CLOCK], "{:?}", r.findings);
        assert!(!r.findings[0].chain.is_empty());
    }

    // ---- v2: classification completeness ----------------------------------

    #[test]
    fn unclassified_solver_or_sim_module_is_a_finding() {
        let src = "pub fn f() -> u32 { 1 }\n".to_string();
        let r = lint_files(&[("rust/src/solver/brand_new.rs".to_string(), src.clone())]);
        assert_eq!(r.findings.len(), 1, "{:?}", r.findings);
        assert_eq!(r.findings[0].rule, rules::RULE_UNCLASSIFIED);
        assert_eq!(r.findings[0].line, 1);
        let r = lint_files(&[("rust/src/sim/new_chaos.rs".to_string(), src.clone())]);
        assert_eq!(r.findings.len(), 1, "{:?}", r.findings);
        assert_eq!(r.findings[0].rule, rules::RULE_UNCLASSIFIED);
        let r = lint_files(&[("rust/src/solver/policy.rs".to_string(), src)]);
        assert!(r.findings.is_empty(), "classified files are silent: {:?}", r.findings);
    }

    #[test]
    fn the_completeness_rule_is_unwaivable() {
        let src = "// lint:allow(unclassified-module) -- trying to opt out\n\
                   pub fn f() -> u32 { 1 }\n";
        let r = lint_files(&[("rust/src/solver/brand_new.rs".to_string(), src.to_string())]);
        let fired: Vec<&'static str> = r.findings.iter().map(|f| f.rule).collect();
        assert!(fired.contains(&rules::RULE_UNCLASSIFIED), "{fired:?}");
        assert!(
            fired.contains(&rules::RULE_WAIVER_SYNTAX),
            "naming the meta-rule in lint:allow must itself be rejected: {fired:?}"
        );
    }

    /// Acceptance demo: deleting the `Deadline::after` source-site waiver
    /// in `util/mod.rs` surfaces a *cross-file* clock chain — the solver
    /// entry points reach it even though `util` has no contract class.
    #[test]
    fn deleting_the_deadline_waiver_surfaces_its_clock_chain() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let mut paths: Vec<PathBuf> = Vec::new();
        for rel in DEFAULT_ROOTS {
            if let Err(e) = collect_rs_files(&root.join(rel), &mut paths) {
                panic!("tree walk failed: {e}");
            }
        }
        paths.sort();
        paths.dedup();
        let mut inputs: Vec<(String, String)> = Vec::new();
        for p in &paths {
            let disp =
                p.strip_prefix(root).unwrap_or(p.as_path()).to_string_lossy().replace('\\', "/");
            if disp.contains("lint/fixtures") {
                continue;
            }
            let src = match std::fs::read_to_string(p) {
                Ok(s) => s,
                Err(e) => panic!("reading {disp}: {e}"),
            };
            let src = if disp == "rust/src/util/mod.rs" {
                src.lines().filter(|l| !l.contains("lint:allow")).map(|l| format!("{l}\n")).collect()
            } else {
                src
            };
            inputs.push((disp, src));
        }
        let r = lint_files(&inputs);
        let clocks: Vec<&Finding> = r
            .findings
            .iter()
            .filter(|f| f.rule == RULE_CLOCK && f.path == "rust/src/util/mod.rs")
            .collect();
        assert!(
            !clocks.is_empty(),
            "stripping the Deadline waiver must surface its clock chain: {:?}",
            r.findings
        );
        assert!(
            clocks[0].message.contains("reachable from a contract entry point"),
            "{}",
            clocks[0].message
        );
        assert!(!clocks[0].chain.is_empty());
    }

    #[test]
    fn tree_report_serializes_to_json() {
        let r = lint_files(&xchain_files(include_str!("fixtures/xchain_helper_waived.rs")));
        let json = r.to_json();
        assert!(json.contains("\"findings\": []"), "{json}");
        assert!(json.contains("\"used\": true"), "{json}");
        assert!(json.contains("\"unresolved_rate\": 0.000000"), "{json}");
        assert!(json.contains("\"files\": 4"), "{json}");
    }

    /// Acceptance demo: reverting an online-path panic fix (reintroducing
    /// an `unwrap`) makes the lint exit non-zero.
    #[test]
    fn reintroducing_a_coordinator_unwrap_fires() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let path = "rust/src/coordinator/mod.rs";
        let src = match std::fs::read_to_string(root.join(path)) {
            Ok(s) => s,
            Err(e) => panic!("reading {path}: {e}"),
        };
        let clean = lint_source(path, &src);
        assert!(clean.findings.is_empty(), "{:?}", clean.findings);
        let dirty = format!("{src}\nfn regressed(g: Option<u32>) -> u32 {{ g.unwrap() }}\n");
        let r = lint_source(path, &dirty);
        assert_eq!(rules_fired(&r), [RULE_PANIC]);
    }
}
