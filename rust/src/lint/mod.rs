//! `saturn-lint` — a dependency-free static analyzer enforcing the repo's
//! determinism and panic-freedom contracts at CI time.
//!
//! The annealer's two core contracts — delta ≡ full-replay and
//! bit-identical trajectories for every thread count — plus the online
//! path's panic-freedom are checked *dynamically* by property tests, which
//! catch a stray `Instant::now`, an ambient RNG draw, or a `HashMap`
//! iteration only probabilistically and long after the offending line
//! landed. This module checks them *statically*: a minimal Rust lexer
//! ([`lexer`]) feeds token-sequence rules ([`rules`]) scoped by a per-file
//! module classification ([`classify`]), so rules match real tokens, never
//! text inside strings or docs, and `#[cfg(test)]`/`#[test]` regions (and
//! `tests/`/`benches/` trees) are exempt.
//!
//! Run it as `cargo run --release --bin saturn-lint` (CI does), or call
//! [`lint_tree`] / [`lint_source`] directly. See `LINTS.md` for the rule
//! catalogue.
//!
//! # Waivers
//!
//! A finding can be waived with a justified inline comment on the same
//! line or the line directly above the offending code:
//!
//! ```text
//! // lint:allow(clock-in-evaluator) -- coordinator-side budget start,
//! //                                   never read by workers
//! ```
//!
//! The justification after `--` is mandatory — a bare waiver is itself a
//! finding (`waiver-syntax`), as is a waiver that no longer suppresses
//! anything (`unused-waiver`) or one naming an unknown rule. Waivers are
//! only recognized in plain `//` comments (never `///`/`//!` docs, so
//! documenting the syntax cannot accidentally waive). Inventory them with
//! `saturn-lint --list-waivers`.

pub mod lexer;
pub mod rules;

use self::lexer::{tokenize, TokKind, Token};
use self::rules::{
    check_clock, check_debug_assert, check_panic, check_rng, check_unordered, RawFinding,
    RULE_UNUSED_WAIVER, RULE_WAIVER_SYNTAX, WAIVABLE_RULES,
};
use std::fmt;
use std::path::{Path, PathBuf};

/// The roots CI lints, relative to the repository root.
pub const DEFAULT_ROOTS: [&str; 4] = ["rust/src", "rust/benches", "rust/tests", "examples"];

/// Determinism-contract files: the delta kernel, the speculative anneal
/// engine, the objective layer, the optimizer driving both, the planning
/// context they all read, and the expected-loss risk pricing scored
/// inside every evaluator. Together with `src/sim/` these are the
/// modules where delta ≡ full-replay and thread-count trajectory parity
/// must hold bit-for-bit.
const DETERMINISM_FILES: [&str; 6] = [
    "src/solver/delta.rs",
    "src/solver/anneal.rs",
    "src/solver/objective.rs",
    "src/solver/joint.rs",
    "src/solver/policy.rs",
    "src/solver/risk.rs",
];

/// Which rule families apply to a file, derived from its path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FileClass {
    /// Determinism-contract module: clock + unordered-iteration rules.
    pub determinism: bool,
    /// Inside `solver`/`sim`: the ambient-rng rule.
    pub rng_scope: bool,
    /// Online ingest path (`online`, `coordinator`) and the simulator's
    /// chaos state machine (`sim/chaos.rs` — the failure-handling path
    /// must degrade, never panic): panic-freedom rule.
    pub panic_sensitive: bool,
    /// `tests/` or `benches/` tree: all rules exempt (waivers still
    /// parsed so malformed ones are reported).
    pub test_only: bool,
}

/// Classify a repo-relative path (`rust/src/solver/delta.rs`, …).
pub fn classify(path: &str) -> FileClass {
    let p = path.replace('\\', "/");
    let test_only = p.contains("/tests/")
        || p.starts_with("tests/")
        || p.contains("/benches/")
        || p.starts_with("benches/");
    let determinism = DETERMINISM_FILES.iter().any(|s| p.ends_with(s)) || p.contains("src/sim/");
    FileClass {
        determinism,
        rng_scope: p.contains("src/solver/") || p.contains("src/sim/"),
        panic_sensitive: p.contains("src/online/")
            || p.contains("src/coordinator/")
            || p.ends_with("src/sim/chaos.rs"),
        test_only,
    }
}

/// One reported lint violation.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Repo-relative path.
    pub path: String,
    /// 1-based line.
    pub line: u32,
    /// Rule identifier (see [`rules`]).
    pub rule: &'static str,
    /// Explanation of the violation.
    pub message: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.path, self.line, self.rule, self.message)
    }
}

/// One parsed `lint:allow` waiver.
#[derive(Debug, Clone)]
pub struct Waiver {
    /// Repo-relative path.
    pub path: String,
    /// 1-based line of the waiver comment.
    pub line: u32,
    /// Rules the waiver covers.
    pub rules: Vec<String>,
    /// The mandatory justification after `--`.
    pub justification: String,
}

impl fmt::Display for Waiver {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: {} -- {}", self.path, self.line, self.rules.join(", "), self.justification)
    }
}

/// Lint result for one file.
#[derive(Debug, Clone, Default)]
pub struct FileReport {
    /// Findings after waiver filtering, sorted by line.
    pub findings: Vec<Finding>,
    /// All waivers in the file (used or not).
    pub waivers: Vec<Waiver>,
}

/// Lint result for a tree of files.
#[derive(Debug, Clone, Default)]
pub struct TreeReport {
    /// All findings, sorted by (path, line).
    pub findings: Vec<Finding>,
    /// All waivers, in path order.
    pub waivers: Vec<Waiver>,
    /// Number of files scanned.
    pub files: usize,
}

/// Index one past the matching `]` of an attribute starting at `i`
/// (`#` `[` …), or `None` if `i` does not start an attribute.
fn attr_end(code: &[Token], i: usize) -> Option<usize> {
    let at = |k: usize, s: &str| code.get(k).is_some_and(|t| t.kind == TokKind::Punct && t.text == s);
    if !(at(i, "#") && at(i + 1, "[")) {
        return None;
    }
    let mut depth = 1i32;
    let mut j = i + 2;
    while j < code.len() {
        if code[j].kind == TokKind::Punct {
            match code[j].text.as_str() {
                "[" => depth += 1,
                "]" => {
                    depth -= 1;
                    if depth == 0 {
                        return Some(j + 1);
                    }
                }
                _ => {}
            }
        }
        j += 1;
    }
    None
}

/// True if the attribute spanning `i..end` is `#[test]` or `#[cfg(test)]`.
fn is_test_attr(code: &[Token], i: usize, end: usize) -> bool {
    let c: Vec<&str> = code[i + 2..end - 1].iter().map(|t| t.text.as_str()).collect();
    c == ["test"] || c == ["cfg", "(", "test", ")"]
}

/// Index of the `}` matching the `{` at `open` (last token if unbalanced).
fn match_brace(code: &[Token], open: usize) -> usize {
    let mut depth = 0i32;
    let mut j = open;
    while j < code.len() {
        if code[j].kind == TokKind::Punct {
            match code[j].text.as_str() {
                "{" => depth += 1,
                "}" => {
                    depth -= 1;
                    if depth == 0 {
                        return j;
                    }
                }
                _ => {}
            }
        }
        j += 1;
    }
    code.len().saturating_sub(1)
}

/// Inclusive line ranges covered by `#[cfg(test)]` / `#[test]` items:
/// from the attribute to the item's closing brace (or terminating `;`).
fn test_exempt_ranges(code: &[Token]) -> Vec<(u32, u32)> {
    let mut ranges = Vec::new();
    let mut i = 0usize;
    while i < code.len() {
        let Some(end) = attr_end(code, i) else {
            i += 1;
            continue;
        };
        let start_line = code[i].line;
        let mut is_test = is_test_attr(code, i, end);
        // absorb the whole attribute run; any test attr marks the item
        let mut k = end;
        while let Some(e2) = attr_end(code, k) {
            is_test = is_test || is_test_attr(code, k, e2);
            k = e2;
        }
        if !is_test {
            i = k;
            continue;
        }
        // the item body: first `{` outside parens/brackets, or a bare `;`
        let mut depth = 0i32;
        let mut found = false;
        while k < code.len() {
            if code[k].kind == TokKind::Punct {
                match code[k].text.as_str() {
                    "(" | "[" => depth += 1,
                    ")" | "]" => depth -= 1,
                    "{" if depth == 0 => {
                        let close = match_brace(code, k);
                        ranges.push((start_line, code[close].line));
                        k = close + 1;
                        found = true;
                    }
                    ";" if depth == 0 => {
                        ranges.push((start_line, code[k].line));
                        k += 1;
                        found = true;
                    }
                    _ => {}
                }
            }
            if found {
                break;
            }
            k += 1;
        }
        if !found {
            let last = code.last().map(|t| t.line).unwrap_or(start_line);
            ranges.push((start_line, last));
        }
        i = k;
    }
    ranges
}

fn in_exempt(ranges: &[(u32, u32)], line: u32) -> bool {
    ranges.iter().any(|&(a, b)| a <= line && line <= b)
}

/// Parsed waiver or a syntax error message for a malformed one.
enum WaiverParse {
    NotAWaiver,
    Ok(Vec<String>, String),
    Bad(String),
}

/// Parse a `lint:allow` waiver out of one line comment. Doc comments
/// (`///`, `//!`) never carry waivers.
fn parse_waiver(comment: &str) -> WaiverParse {
    let body = match comment.strip_prefix("//") {
        Some(b) => b,
        None => return WaiverParse::NotAWaiver,
    };
    if body.starts_with('/') || body.starts_with('!') {
        return WaiverParse::NotAWaiver;
    }
    let body = body.trim_start();
    let Some(rest) = body.strip_prefix("lint:allow") else {
        return WaiverParse::NotAWaiver;
    };
    let rest = rest.trim_start();
    let Some(rest) = rest.strip_prefix('(') else {
        return WaiverParse::Bad("waiver must name its rules: lint:allow(<rule>)".to_string());
    };
    let Some(close) = rest.find(')') else {
        return WaiverParse::Bad("unclosed rule list in lint:allow(".to_string());
    };
    let mut names = Vec::new();
    for raw in rest[..close].split(',') {
        let name = raw.trim();
        if name.is_empty() {
            return WaiverParse::Bad("empty rule name in lint:allow(...)".to_string());
        }
        if !WAIVABLE_RULES.contains(&name) {
            return WaiverParse::Bad(format!(
                "unknown or unwaivable rule `{name}` (waivable: {})",
                WAIVABLE_RULES.join(", ")
            ));
        }
        names.push(name.to_string());
    }
    let after = rest[close + 1..].trim_start();
    let Some(just) = after.strip_prefix("--") else {
        return WaiverParse::Bad(
            "waiver without justification; write: lint:allow(<rule>) -- <why this is sound>"
                .to_string(),
        );
    };
    let just = just.trim();
    if just.is_empty() {
        return WaiverParse::Bad(
            "waiver without justification; write: lint:allow(<rule>) -- <why this is sound>"
                .to_string(),
        );
    }
    WaiverParse::Ok(names, just.to_string())
}

/// Lint one file's source. `path` is the repo-relative path used both for
/// classification and reporting, so fixtures can be linted *as if* they
/// lived in a contract module.
pub fn lint_source(path: &str, src: &str) -> FileReport {
    let class = classify(path);
    let toks = tokenize(src);
    let mut findings: Vec<Finding> = Vec::new();
    let mut waivers: Vec<Waiver> = Vec::new();
    let mut code: Vec<Token> = Vec::with_capacity(toks.len());
    for t in toks {
        match t.kind {
            TokKind::LineComment => match parse_waiver(&t.text) {
                WaiverParse::NotAWaiver => {}
                WaiverParse::Ok(rules, justification) => waivers.push(Waiver {
                    path: path.to_string(),
                    line: t.line,
                    rules,
                    justification,
                }),
                WaiverParse::Bad(msg) => findings.push(Finding {
                    path: path.to_string(),
                    line: t.line,
                    rule: RULE_WAIVER_SYNTAX,
                    message: msg,
                }),
            },
            TokKind::BlockComment => {}
            _ => code.push(t),
        }
    }
    let exempt = test_exempt_ranges(&code);

    let mut raw: Vec<RawFinding> = Vec::new();
    if !class.test_only {
        if class.determinism {
            check_clock(&code, &mut raw);
            check_unordered(&code, &mut raw);
        }
        if class.rng_scope {
            check_rng(&code, &mut raw);
        }
        if class.panic_sensitive {
            check_panic(&code, &mut raw);
        }
        check_debug_assert(&code, &mut raw);
    }
    raw.retain(|f| !in_exempt(&exempt, f.line));

    let mut used = vec![false; waivers.len()];
    for f in raw {
        let mut waived = false;
        for (wi, w) in waivers.iter().enumerate() {
            let covers = w.line == f.line || w.line + 1 == f.line;
            if covers && w.rules.iter().any(|r| r == f.rule) {
                used[wi] = true;
                waived = true;
            }
        }
        if !waived {
            findings.push(Finding {
                path: path.to_string(),
                line: f.line,
                rule: f.rule,
                message: f.message,
            });
        }
    }
    for (wi, w) in waivers.iter().enumerate() {
        if !used[wi] && !class.test_only && !in_exempt(&exempt, w.line) {
            findings.push(Finding {
                path: path.to_string(),
                line: w.line,
                rule: RULE_UNUSED_WAIVER,
                message: format!(
                    "waiver for `{}` suppresses nothing; delete it or move it next to \
                     the finding it covers",
                    w.rules.join(", ")
                ),
            });
        }
    }
    findings.sort_by(|a, b| a.line.cmp(&b.line).then(a.rule.cmp(b.rule)));
    FileReport { findings, waivers }
}

/// Recursively collect `.rs` files (deterministic order: sorted by name).
fn collect_rs_files(path: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    if path.is_dir() {
        let mut entries: Vec<PathBuf> = std::fs::read_dir(path)?
            .map(|e| e.map(|d| d.path()))
            .collect::<std::io::Result<Vec<PathBuf>>>()?;
        entries.sort();
        for e in entries {
            collect_rs_files(&e, out)?;
        }
    } else if path.extension().is_some_and(|e| e == "rs") {
        out.push(path.to_path_buf());
    }
    Ok(())
}

/// Lint every `.rs` file under `root`-relative paths `rels`. The lint's
/// own rule fixtures (`lint/fixtures/`) are skipped — they deliberately
/// violate every rule and are exercised by the fixture tests instead.
pub fn lint_tree(root: &Path, rels: &[&str]) -> std::io::Result<TreeReport> {
    let mut files: Vec<PathBuf> = Vec::new();
    for rel in rels {
        let p = root.join(rel);
        if !p.exists() {
            return Err(std::io::Error::new(
                std::io::ErrorKind::NotFound,
                format!("no such path: {}", p.display()),
            ));
        }
        collect_rs_files(&p, &mut files)?;
    }
    files.sort();
    files.dedup();
    let mut report = TreeReport::default();
    for f in &files {
        let disp = f
            .strip_prefix(root)
            .unwrap_or(f.as_path())
            .to_string_lossy()
            .replace('\\', "/");
        if disp.contains("lint/fixtures") {
            continue;
        }
        let src = std::fs::read_to_string(f)?;
        let fr = lint_source(&disp, &src);
        report.files += 1;
        report.findings.extend(fr.findings);
        report.waivers.extend(fr.waivers);
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::rules::{RULE_CLOCK, RULE_DEBUG_ASSERT, RULE_PANIC, RULE_RNG, RULE_UNORDERED};
    use super::*;

    fn rules_fired(report: &FileReport) -> Vec<&'static str> {
        report.findings.iter().map(|f| f.rule).collect()
    }

    // ---- classification --------------------------------------------------

    #[test]
    fn classification_matches_contract_map() {
        let c = classify("rust/src/solver/delta.rs");
        assert!(c.determinism && c.rng_scope && !c.panic_sensitive && !c.test_only);
        let c = classify("rust/src/sim/mod.rs");
        assert!(c.determinism && c.rng_scope && !c.panic_sensitive);
        let c = classify("rust/src/sim/chaos.rs");
        assert!(
            c.determinism && c.rng_scope && c.panic_sensitive,
            "the chaos state machine carries every contract: deterministic AND panic-free"
        );
        let c = classify("rust/src/solver/milp.rs");
        assert!(!c.determinism && c.rng_scope, "milp is rng-scoped but not a contract file");
        let c = classify("rust/src/solver/risk.rs");
        assert!(
            c.determinism && c.rng_scope && !c.panic_sensitive,
            "risk pricing runs inside every evaluator: deterministic, DetRng-only"
        );
        let c = classify("rust/src/online/mod.rs");
        assert!(c.panic_sensitive && !c.determinism);
        let c = classify("rust/src/coordinator/mod.rs");
        assert!(c.panic_sensitive);
        let c = classify("rust/tests/prop_invariants.rs");
        assert!(c.test_only);
        let c = classify("rust/benches/bench_solver.rs");
        assert!(c.test_only);
        let c = classify("examples/quickstart.rs");
        assert!(!c.determinism && !c.rng_scope && !c.panic_sensitive && !c.test_only);
        let c = classify("rust/src/util/mod.rs");
        assert!(!c.determinism && !c.rng_scope, "util::Deadline is the sanctioned clock site");
    }

    // ---- test-region exemption -------------------------------------------

    #[test]
    fn cfg_test_modules_are_exempt() {
        let src = "fn live() {}\n\
                   #[cfg(test)]\n\
                   mod tests {\n\
                       #[test]\n\
                       fn t() { x.unwrap(); let i = std::time::Instant::now(); }\n\
                   }\n";
        let r = lint_source("rust/src/online/mod.rs", src);
        assert!(r.findings.is_empty(), "{:?}", r.findings);
        let r = lint_source("rust/src/solver/anneal.rs", src);
        assert!(r.findings.is_empty(), "{:?}", r.findings);
    }

    #[test]
    fn test_attribute_on_a_single_fn_is_exempt_but_neighbors_are_not() {
        let src = "#[test]\n\
                   fn t() { x.unwrap(); }\n\
                   fn live() { y.unwrap(); }\n";
        let r = lint_source("rust/src/online/mod.rs", src);
        assert_eq!(rules_fired(&r), [RULE_PANIC]);
        assert_eq!(r.findings[0].line, 3);
    }

    #[test]
    fn cfg_not_test_is_not_exempt() {
        let src = "#[cfg(not(test))]\nfn live() { x.unwrap(); }\n";
        let r = lint_source("rust/src/online/mod.rs", src);
        assert_eq!(rules_fired(&r), [RULE_PANIC]);
    }

    // ---- waivers ----------------------------------------------------------

    #[test]
    fn waiver_on_previous_line_suppresses_and_is_inventoried() {
        let src = "// lint:allow(panic-freedom) -- startup-only invariant, documented\n\
                   fn live() { x.unwrap(); }\n";
        let r = lint_source("rust/src/online/mod.rs", src);
        assert!(r.findings.is_empty(), "{:?}", r.findings);
        assert_eq!(r.waivers.len(), 1);
        assert_eq!(r.waivers[0].rules, ["panic-freedom"]);
        assert!(r.waivers[0].justification.contains("startup-only"));
    }

    #[test]
    fn trailing_waiver_on_the_same_line_suppresses() {
        let src = "fn live() { x.unwrap(); } // lint:allow(panic-freedom) -- demo harness\n";
        let r = lint_source("rust/src/online/mod.rs", src);
        assert!(r.findings.is_empty(), "{:?}", r.findings);
    }

    #[test]
    fn waiver_without_justification_is_an_error() {
        let src = "// lint:allow(panic-freedom)\nfn live() { x.unwrap(); }\n";
        let r = lint_source("rust/src/online/mod.rs", src);
        // the malformed waiver does not suppress, so both errors surface
        let fired = rules_fired(&r);
        assert!(fired.contains(&rules::RULE_WAIVER_SYNTAX), "{fired:?}");
        assert!(fired.contains(&RULE_PANIC), "{fired:?}");
    }

    #[test]
    fn waiver_for_unknown_rule_is_an_error() {
        let src = "// lint:allow(made-up-rule) -- because\nfn live() {}\n";
        let r = lint_source("rust/src/online/mod.rs", src);
        assert_eq!(rules_fired(&r), [rules::RULE_WAIVER_SYNTAX]);
        assert!(r.findings[0].message.contains("made-up-rule"));
    }

    #[test]
    fn unused_waiver_is_an_error() {
        let src = "// lint:allow(panic-freedom) -- nothing here panics anymore\nfn live() {}\n";
        let r = lint_source("rust/src/online/mod.rs", src);
        assert_eq!(rules_fired(&r), [RULE_UNUSED_WAIVER]);
    }

    #[test]
    fn doc_comments_never_waive() {
        let src = "/// lint:allow(panic-freedom) -- docs cannot waive\nfn live() { x.unwrap(); }\n";
        let r = lint_source("rust/src/online/mod.rs", src);
        assert_eq!(rules_fired(&r), [RULE_PANIC]);
        assert!(r.waivers.is_empty());
    }

    #[test]
    fn one_waiver_covers_a_multi_rule_list() {
        let src = "// lint:allow(clock-in-evaluator, ambient-rng) -- calibration-only path\n\
                   fn f() { let t = Instant::now(); let h = RandomState::new(); }\n";
        let r = lint_source("rust/src/solver/delta.rs", src);
        assert!(r.findings.is_empty(), "{:?}", r.findings);
        assert_eq!(r.waivers[0].rules.len(), 2);
    }

    // ---- fixtures: each rule fires on its bad twin, not its good twin ----

    #[test]
    fn fixture_clock_in_evaluator() {
        let bad = lint_source("rust/src/solver/anneal.rs", include_str!("fixtures/clock_bad.rs"));
        assert!(!bad.findings.is_empty());
        assert!(bad.findings.iter().all(|f| f.rule == RULE_CLOCK), "{:?}", bad.findings);
        let good = lint_source("rust/src/solver/anneal.rs", include_str!("fixtures/clock_good.rs"));
        assert!(good.findings.is_empty(), "{:?}", good.findings);
    }

    #[test]
    fn fixture_unordered_iteration() {
        let bad = lint_source("rust/src/sim/mod.rs", include_str!("fixtures/unordered_bad.rs"));
        assert!(bad.findings.len() >= 3, "{:?}", bad.findings);
        assert!(bad.findings.iter().all(|f| f.rule == RULE_UNORDERED), "{:?}", bad.findings);
        let good = lint_source("rust/src/sim/mod.rs", include_str!("fixtures/unordered_good.rs"));
        assert!(good.findings.is_empty(), "{:?}", good.findings);
    }

    #[test]
    fn fixture_ambient_rng() {
        let bad = lint_source("rust/src/solver/spase.rs", include_str!("fixtures/rng_bad.rs"));
        assert!(!bad.findings.is_empty());
        assert!(bad.findings.iter().all(|f| f.rule == RULE_RNG), "{:?}", bad.findings);
        let good = lint_source("rust/src/solver/spase.rs", include_str!("fixtures/rng_good.rs"));
        assert!(good.findings.is_empty(), "{:?}", good.findings);
    }

    #[test]
    fn fixture_panic_freedom() {
        let bad = lint_source("rust/src/online/mod.rs", include_str!("fixtures/panic_bad.rs"));
        assert!(bad.findings.len() >= 5, "{:?}", bad.findings);
        assert!(bad.findings.iter().all(|f| f.rule == RULE_PANIC), "{:?}", bad.findings);
        let good = lint_source("rust/src/online/mod.rs", include_str!("fixtures/panic_good.rs"));
        assert!(good.findings.is_empty(), "{:?}", good.findings);
    }

    #[test]
    fn fixture_chaos_panic() {
        let bad = lint_source("rust/src/sim/chaos.rs", include_str!("fixtures/chaos_panic_bad.rs"));
        assert!(bad.findings.len() >= 4, "{:?}", bad.findings);
        assert!(bad.findings.iter().all(|f| f.rule == RULE_PANIC), "{:?}", bad.findings);
        let good =
            lint_source("rust/src/sim/chaos.rs", include_str!("fixtures/chaos_panic_good.rs"));
        assert!(good.findings.is_empty(), "{:?}", good.findings);
    }

    #[test]
    fn fixture_risk_determinism() {
        let bad = lint_source("rust/src/solver/risk.rs", include_str!("fixtures/risk_bad.rs"));
        let fired = rules_fired(&bad);
        assert!(fired.contains(&RULE_CLOCK), "{fired:?}");
        assert!(fired.contains(&RULE_UNORDERED), "{fired:?}");
        assert!(fired.contains(&RULE_RNG), "{fired:?}");
        let good = lint_source("rust/src/solver/risk.rs", include_str!("fixtures/risk_good.rs"));
        assert!(good.findings.is_empty(), "{:?}", good.findings);
    }

    #[test]
    fn fixture_debug_assert_side_effect() {
        let bad =
            lint_source("rust/src/solver/anneal.rs", include_str!("fixtures/debug_assert_bad.rs"));
        assert!(!bad.findings.is_empty());
        assert!(bad.findings.iter().all(|f| f.rule == RULE_DEBUG_ASSERT), "{:?}", bad.findings);
        let good =
            lint_source("rust/src/solver/anneal.rs", include_str!("fixtures/debug_assert_good.rs"));
        assert!(good.findings.is_empty(), "{:?}", good.findings);
    }

    #[test]
    fn fixture_waivers() {
        let bad = lint_source("rust/src/online/mod.rs", include_str!("fixtures/waiver_bad.rs"));
        let fired = rules_fired(&bad);
        assert!(fired.contains(&rules::RULE_WAIVER_SYNTAX), "{fired:?}");
        assert!(fired.contains(&RULE_UNUSED_WAIVER), "{fired:?}");
        let good = lint_source("rust/src/online/mod.rs", include_str!("fixtures/waiver_good.rs"));
        assert!(good.findings.is_empty(), "{:?}", good.findings);
        assert!(!good.waivers.is_empty());
    }

    // ---- the real tree ----------------------------------------------------

    #[test]
    fn real_tree_is_lint_clean() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let report = match lint_tree(root, &DEFAULT_ROOTS) {
            Ok(r) => r,
            Err(e) => panic!("tree walk failed: {e}"),
        };
        assert!(report.files > 50, "walker found suspiciously few files: {}", report.files);
        let msgs: Vec<String> = report.findings.iter().map(|f| f.to_string()).collect();
        assert!(report.findings.is_empty(), "the tree must be lint-clean:\n{}", msgs.join("\n"));
        assert!(!report.waivers.is_empty(), "the joint.rs deadline waivers should be inventoried");
    }

    /// Acceptance demo: deleting any one waiver comment makes the lint
    /// exit non-zero — here, the `joint.rs` deadline-read waivers.
    #[test]
    fn deleting_a_waiver_surfaces_the_underlying_finding() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let path = "rust/src/solver/joint.rs";
        let src = match std::fs::read_to_string(root.join(path)) {
            Ok(s) => s,
            Err(e) => panic!("reading {path}: {e}"),
        };
        let with = lint_source(path, &src);
        assert!(with.findings.is_empty(), "{:?}", with.findings);
        let clock_waivers =
            with.waivers.iter().filter(|w| w.rules.iter().any(|r| r == RULE_CLOCK)).count();
        assert!(clock_waivers >= 2, "expected the two deadline-read waivers, saw {clock_waivers}");
        let stripped: String = src
            .lines()
            .filter(|l| !l.contains("lint:allow"))
            .map(|l| format!("{l}\n"))
            .collect();
        let without = lint_source(path, &stripped);
        let clocks = without.findings.iter().filter(|f| f.rule == RULE_CLOCK).count();
        assert!(clocks >= 2, "stripping waivers must surface the clock reads: {:?}", without.findings);
    }

    /// Acceptance demo: reverting an online-path panic fix (reintroducing
    /// an `unwrap`) makes the lint exit non-zero.
    #[test]
    fn reintroducing_a_coordinator_unwrap_fires() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR"));
        let path = "rust/src/coordinator/mod.rs";
        let src = match std::fs::read_to_string(root.join(path)) {
            Ok(s) => s,
            Err(e) => panic!("reading {path}: {e}"),
        };
        let clean = lint_source(path, &src);
        assert!(clean.findings.is_empty(), "{:?}", clean.findings);
        let dirty = format!("{src}\nfn regressed(g: Option<u32>) -> u32 {{ g.unwrap() }}\n");
        let r = lint_source(path, &dirty);
        assert_eq!(rules_fired(&r), [RULE_PANIC]);
    }
}
